"""REAP: record-and-prefetch working sets (§3.4.2).

The recorder captures which *resource units* a sample request actually
touches.  For an LLM instance the unit keys are:

  ``("w", path, sub)``   weight unit (whole leaf, or an expert / embed-block
                         slice — DESIGN.md §2's MoE/embedding insight)
  ``("kv", layer, page)`` a KV-cache pool page

The recorded set becomes the REAP file's scatter io-vector: on wake-up it
is prefetched with one batched sequential read; everything else stays
swapped until page-faulted.

The recorder preserves **first-touch order** (insertion-ordered dicts used
as ordered sets): the REAP file is laid out in that order, so the streamed
wake pipeline (:mod:`repro.core.inflate`) restores units in the order the
sample request needed them — the prefill-critical prefix arrives first and
compute can start while the tail is still inflating.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Hashable, Set, Tuple


@dataclass
class ReapRecorder:
    recording: bool = False
    #: insertion-ordered set: key -> None, first-touch order of this session
    seen: Dict[Hashable, None] = field(default_factory=dict)
    #: survives across record sessions — the stable working set (REAP's
    #: observation: the set is stable across invocations of one function).
    #: Insertion-ordered: a unit keeps the position of its FIRST touch ever.
    stable: Dict[Hashable, None] = field(default_factory=dict)
    #: how many deflate cycles each unit has missed the working set — the
    #: coldness signal the SwapStore's compression tiers key off
    misses: Dict[Hashable, int] = field(default_factory=dict)

    def start(self) -> None:
        self.recording = True
        self.seen = {}

    def record(self, key: Hashable) -> None:
        if self.recording and key not in self.seen:
            self.seen[key] = None

    def record_many(self, keys) -> None:
        if self.recording:
            for k in keys:
                if k not in self.seen:
                    self.seen[k] = None

    def stop(self) -> FrozenSet[Hashable]:
        self.recording = False
        # union: pages touched by any recorded invocation are kept (stable
        # working set across invocations per REAP); existing units keep
        # their original touch position, new units append in touch order
        for k in self.seen:
            if k not in self.stable:
                self.stable[k] = None
        return frozenset(self.stable)

    @property
    def working_set(self) -> FrozenSet[Hashable]:
        return frozenset(self.stable)

    @property
    def ordered_working_set(self) -> Tuple[Hashable, ...]:
        """The stable working set in first-touch order — the REAP file's
        on-disk layout and the wake pipeline's streaming order."""
        return tuple(self.stable)

    def note_misses(self, keys) -> None:
        """A deflate cycle sent these units to the page-fault tier (they
        missed the working set): bump their coldness counters."""
        for k in keys:
            self.misses[k] = self.misses.get(k, 0) + 1

    def miss_count(self, key: Hashable) -> int:
        return self.misses.get(key, 0)

    def prune_misses(self, live: Set[Hashable]) -> None:
        """Drop coldness counters for keys that no longer exist (closed
        sessions' KV pages): the dict must not grow with session churn.
        Weight-unit history is preserved — the caller passes the full unit
        catalog as live."""
        self.misses = {k: v for k, v in self.misses.items() if k in live}

    def forget(self) -> None:
        self.stable = {}
        self.seen = {}
        self.misses = {}

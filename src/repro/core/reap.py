"""REAP: record-and-prefetch working sets (§3.4.2).

The recorder captures which *resource units* a sample request actually
touches.  For an LLM instance the unit keys are:

  ``("w", path, sub)``   weight unit (whole leaf, or an expert / embed-block
                         slice — DESIGN.md §2's MoE/embedding insight)
  ``("kv", layer, page)`` a KV-cache pool page

The recorded set becomes the REAP file's scatter io-vector: on wake-up it
is prefetched with one batched sequential read; everything else stays
swapped until page-faulted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Hashable, Set


@dataclass
class ReapRecorder:
    recording: bool = False
    seen: Set[Hashable] = field(default_factory=set)
    #: survives across record sessions — the stable working set (REAP's
    #: observation: the set is stable across invocations of one function)
    stable: Set[Hashable] = field(default_factory=set)
    #: how many deflate cycles each unit has missed the working set — the
    #: coldness signal the SwapStore's compression tiers key off
    misses: Dict[Hashable, int] = field(default_factory=dict)

    def start(self) -> None:
        self.recording = True
        self.seen = set()

    def record(self, key: Hashable) -> None:
        if self.recording:
            self.seen.add(key)

    def record_many(self, keys) -> None:
        if self.recording:
            self.seen.update(keys)

    def stop(self) -> FrozenSet[Hashable]:
        self.recording = False
        # union: pages touched by any recorded invocation are kept (stable
        # working set across invocations per REAP)
        self.stable |= self.seen
        return frozenset(self.stable)

    @property
    def working_set(self) -> FrozenSet[Hashable]:
        return frozenset(self.stable)

    def note_misses(self, keys) -> None:
        """A deflate cycle sent these units to the page-fault tier (they
        missed the working set): bump their coldness counters."""
        for k in keys:
            self.misses[k] = self.misses.get(k, 0) + 1

    def miss_count(self, key: Hashable) -> int:
        return self.misses.get(key, 0)

    def prune_misses(self, live: Set[Hashable]) -> None:
        """Drop coldness counters for keys that no longer exist (closed
        sessions' KV pages): the dict must not grow with session churn.
        Weight-unit history is preserved — the caller passes the full unit
        catalog as live."""
        self.misses = {k: v for k, v in self.misses.items() if k in live}

    def forget(self) -> None:
        self.stable = set()
        self.seen = set()
        self.misses = {}

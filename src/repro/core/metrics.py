"""Memory accounting (PSS analogue of the paper's `pmap` methodology) and
latency tracing for the per-state benchmarks (Figs. 6/7).

:class:`LatencyTrace` is thread-safe: the AsyncPlatform's worker pool
records spans concurrently from many serving threads.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — no numpy needed."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[idx]


@dataclass
class MemoryReport:
    instance_id: str
    state: str
    rung: str = ""               # deflation-ladder rung (warm/mmap_clean/...)
    weight_private: int = 0      # resident anonymous weight bytes
    weight_shared_pss: float = 0.0   # shared base weights / num sharers
    kv_rss: int = 0              # pool pages held (RSS)
    kv_pss: float = 0.0          # pool pages / refcount (prefix sharing)
    metadata: int = 0            # kept-alive host objects
    # disk tier (swap + REAP files) — the SwapStore's resident-vs-unique-
    # vs-compressed view.  logical: what verbatim per-sandbox files would
    # hold; stored_pss: fair-share on-disk bytes (dedup'd segments split
    # across referencing units, compressed sizes).
    disk_logical: int = 0
    disk_stored_pss: float = 0.0

    @property
    def pss_total(self) -> float:
        return (self.weight_private + self.weight_shared_pss
                + self.kv_pss + self.metadata)

    @property
    def rss_total(self) -> float:
        return (self.weight_private + self.weight_shared_pss
                + self.kv_rss + self.metadata)


def memory_report(inst, shared_registry=None) -> MemoryReport:
    nshare = 1
    shared_bytes = inst.shared_weight_bytes()
    if shared_registry is not None and inst.base_id:
        nshare = max(1, shared_registry.refcount(inst.base_id))
        if not shared_registry.is_loaded(inst.base_id):
            shared_bytes = 0
    sf = inst.swap_file
    disk_logical = (getattr(sf, "logical_bytes", None) or sf.file_bytes) \
        + inst.reap_file.file_bytes
    # for a StoreClient, file_bytes is already the fair-share (PSS-style)
    # compressed on-disk footprint; for a private SwapFile it is the file
    return MemoryReport(
        instance_id=inst.instance_id,
        state=inst.state.value,
        rung=inst.rung.name.lower(),
        weight_private=inst.weight_bytes(resident_only=True,
                                         include_shared=False),
        weight_shared_pss=shared_bytes / nshare,
        kv_rss=inst.kv_bytes(),
        kv_pss=(inst.pool.pss_bytes(inst.instance_id) if inst.pool else 0)
        + (inst.kv.host_bytes() if inst.kv is not None else 0),
        metadata=inst.metadata_bytes(),
        disk_logical=disk_logical,
        disk_stored_pss=sf.file_bytes + inst.reap_file.file_bytes,
    )


def per_rung_report(manager) -> Dict[str, Dict[str, float]]:
    """Deployment-wide per-rung accounting: how many tenants sit on each
    deflation-ladder rung and what they cost in memory and disk.

    Returns ``{rung: {instances, weight_private, weight_shared_pss,
    kv_rss, pss_total, disk_logical, disk_stored_pss}}`` — the
    ``MemoryReport`` columns aggregated by rung (see the README's
    "Memory governor" section for how to read them)."""
    with manager._lock:
        insts = list(manager.instances.values())
    out: Dict[str, Dict[str, float]] = {}
    for inst in insts:
        rep = memory_report(inst, manager.shared)
        row = out.setdefault(rep.rung, {
            "instances": 0, "weight_private": 0, "weight_shared_pss": 0.0,
            "kv_rss": 0, "pss_total": 0.0, "disk_logical": 0,
            "disk_stored_pss": 0.0})
        row["instances"] += 1
        row["weight_private"] += rep.weight_private
        row["weight_shared_pss"] += rep.weight_shared_pss
        row["kv_rss"] += rep.kv_rss
        row["pss_total"] += rep.pss_total
        row["disk_logical"] += rep.disk_logical
        row["disk_stored_pss"] += rep.disk_stored_pss
    return out


def cluster_report(nodes) -> Dict[str, Dict[str, float]]:
    """Per-node rollup for a cluster of :class:`~repro.cluster.node.Node`:
    tenants, governed bytes vs budget, rung mix, and the store's
    dedup'd on-disk footprint — the columns ``benchmarks/cluster_density``
    renders and the router's rebalance decisions act on."""
    out: Dict[str, Dict[str, float]] = {}
    for node in nodes:
        rungs = per_rung_report(node.manager)
        budget = node.governor.budget_bytes
        store = node.store
        reg = getattr(node.manager, "prefix_registry", None)
        pstats = reg.stats() if reg is not None else {}
        out[node.node_id] = {
            "tenants": sum(r["instances"] for r in rungs.values()),
            "governed_bytes": node.governed_bytes(),
            "budget_bytes": budget if budget is not None else float("inf"),
            "pressure_bytes": node.pressure_bytes(),
            "rungs": {r: int(v["instances"]) for r, v in rungs.items()},
            "disk_stored_bytes": store.live_bytes if store else 0,
            # prefix-registry surface the router's affinity term reads
            "prefix_entries": pstats.get("entries", 0),
            "prefix_resident_bytes": pstats.get("resident_bytes", 0),
            "prefix_adoptions": pstats.get("adoptions", 0),
        }
    return out


class LatencyTrace:
    """Named wall-clock spans, e.g. cold_start / prefill / decode / wake."""

    def __init__(self):
        self.spans: Dict[str, List[float]] = {}
        self._lock = threading.Lock()

    @contextmanager
    def span(self, name: str):
        t0 = time.monotonic()
        try:
            yield
        finally:
            dt = time.monotonic() - t0
            with self._lock:
                self.spans.setdefault(name, []).append(dt)

    def total(self, name: str) -> float:
        return sum(self.spans.get(name, ()))

    def mean(self, name: str) -> Optional[float]:
        xs = self.spans.get(name)
        return sum(xs) / len(xs) if xs else None

    def p(self, name: str, q: float) -> float:
        """Percentile over a span's samples (e.g. ``p("e2e", 99)``)."""
        with self._lock:
            xs = list(self.spans.get(name, ()))
        return percentile(xs, q)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            return {k: sum(v) / len(v) for k, v in self.spans.items()}

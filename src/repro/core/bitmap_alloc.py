"""Bitmap Page Allocator — faithful implementation of Figure 4 (§3.3).

Layout per the paper:
  * pages are grouped into blocks of 1024; the first page of each block is
    reserved as the **control page** (so 1023 allocatable pages per block);
  * the control page holds (a) the free-list ``next`` pointer, (b) an L2
    bitmap of 16 × 64-bit words (one bit per page, 1 = free) plus an L1
    64-bit word whose bit *i* says "L2 word *i* has a free page" — a free
    page is found with exactly two find-first-set operations, O(2);
  * a 16-bit reference count per page (process clone / COW analogue: here,
    KV prefix sharing across requests).

Because no metadata lives *inside* free pages (unlike a buddy allocator's
free-list pointers), an entirely-free block can be returned to the global
heap ("madvise") with zero fix-up — that is the paper's reclamation insight.

``block_id * PAGES_PER_BLOCK + offset`` is the global page id; the control
page of any page is found by masking the low 10 bits (the paper's
"clear the least 22 bits" for 4 MB-aligned blocks).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

PAGES_PER_BLOCK = 1024
USABLE_PER_BLOCK = PAGES_PER_BLOCK - 1        # page 0 is the control page
L2_WORDS = 16

_FULL = np.uint64(0xFFFFFFFFFFFFFFFF)


def _ffs(word: int) -> int:
    """Find-first-set (index of lowest 1 bit)."""
    return (word & -word).bit_length() - 1


@dataclass
class _Block:
    """One 4 MB block: control-page state (Fig. 4)."""

    block_id: int
    next: Optional[int] = None                       # free-list "Next" pointer
    l1: np.uint64 = _FULL                            # 1 = L2 word has free pages
    l2: np.ndarray = field(default_factory=lambda: np.full(L2_WORDS, _FULL,
                                                           np.uint64))
    refcount: np.ndarray = field(default_factory=lambda: np.zeros(
        PAGES_PER_BLOCK, np.uint16))
    free_count: int = USABLE_PER_BLOCK

    def __post_init__(self):
        # page 0 (control page) is never allocatable
        self.l2 = self.l2.copy()
        self.l2[0] &= ~np.uint64(1)

    def find_free(self) -> int:
        """O(2) lookup: first set bit of L1, then of that L2 word."""
        w = _ffs(int(self.l1))
        if w < 0:
            raise RuntimeError("find_free on full block")
        b = _ffs(int(self.l2[w]))
        return w * 64 + b

    def mark_allocated(self, off: int) -> None:
        w, b = divmod(off, 64)
        self.l2[w] &= ~(np.uint64(1) << np.uint64(b))
        if self.l2[w] == 0:
            self.l1 &= ~(np.uint64(1) << np.uint64(w))
        self.free_count -= 1
        self.refcount[off] = 1

    def mark_free(self, off: int) -> None:
        w, b = divmod(off, 64)
        self.l2[w] |= (np.uint64(1) << np.uint64(b))
        self.l1 |= (np.uint64(1) << np.uint64(w))
        self.free_count += 1
        self.refcount[off] = 0

    def is_free(self, off: int) -> bool:
        w, b = divmod(off, 64)
        return bool((int(self.l2[w]) >> b) & 1)


class BitmapPageAllocator:
    """Reclamation-oriented page allocator over a growable block set.

    ``grow`` is the "allocate a 4 MB block from the global heap" hook and
    ``release`` the "return block to global heap / madvise" hook; both get
    the block id.  ``max_blocks`` bounds the heap (allocation beyond raises
    ``MemoryError`` — the platform's memory-pressure signal).
    """

    def __init__(self, max_blocks: int = 1 << 20,
                 grow: Optional[Callable[[int], None]] = None,
                 release: Optional[Callable[[int], None]] = None):
        self.max_blocks = max_blocks
        self.blocks: Dict[int, _Block] = {}
        self.free_head: Optional[int] = None        # free-list head block id
        self._next_block_id = 0
        self._grow = grow
        self._release = release
        self.stats = {"allocs": 0, "frees": 0, "blocks_grown": 0,
                      "blocks_released": 0}

    # -- free-list maintenance (linear linked list, Fig. 4) ----------------
    def _push_free(self, blk: _Block) -> None:
        blk.next = self.free_head
        self.free_head = blk.block_id

    def _pop_free(self) -> Optional[_Block]:
        if self.free_head is None:
            return None
        blk = self.blocks[self.free_head]
        return blk

    def _unlink(self, blk: _Block) -> None:
        if self.free_head == blk.block_id:
            self.free_head = blk.next
            blk.next = None
            return
        cur = self.free_head
        while cur is not None:
            c = self.blocks[cur]
            if c.next == blk.block_id:
                c.next = blk.next
                blk.next = None
                return
            cur = c.next

    # -- public API ---------------------------------------------------------
    def alloc(self) -> int:
        """Allocate one page, returning its global page id."""
        blk = self._pop_free()
        if blk is None:
            if len(self.blocks) >= self.max_blocks:
                raise MemoryError("bitmap allocator: global heap exhausted")
            blk = _Block(self._next_block_id)
            self._next_block_id += 1
            self.blocks[blk.block_id] = blk
            self._push_free(blk)
            self.stats["blocks_grown"] += 1
            if self._grow:
                self._grow(blk.block_id)
        off = blk.find_free()
        blk.mark_allocated(off)
        if blk.free_count == 0:
            self._unlink(blk)
        self.stats["allocs"] += 1
        return blk.block_id * PAGES_PER_BLOCK + off

    def alloc_many(self, n: int) -> List[int]:
        return [self.alloc() for _ in range(n)]

    def _blk_off(self, page: int):
        # control-page lookup by masking low bits — no lookup table (§3.3)
        blk_id = page >> 10
        off = page & (PAGES_PER_BLOCK - 1)
        blk = self.blocks.get(blk_id)
        if blk is None or off == 0 or blk.is_free(off):
            raise ValueError(f"page {page} not allocated")
        return blk, off

    def incref(self, page: int) -> int:
        """Lockless atomic_fetch_add analogue (COW / clone sharing)."""
        blk, off = self._blk_off(page)
        if blk.refcount[off] == np.iinfo(np.uint16).max:
            raise OverflowError("refcount overflow")
        blk.refcount[off] += 1
        return int(blk.refcount[off])

    def decref(self, page: int) -> bool:
        """Decrement; frees the page at zero.  Returns True when freed."""
        blk, off = self._blk_off(page)
        blk.refcount[off] -= 1
        if blk.refcount[off] > 0:
            return False
        was_full = blk.free_count == 0
        blk.mark_free(off)
        self.stats["frees"] += 1
        if was_full:
            self._push_free(blk)
        if blk.free_count == USABLE_PER_BLOCK:
            self._reclaim_block(blk)
        return True

    free = decref

    def refcount(self, page: int) -> int:
        blk, off = self._blk_off(page)
        return int(blk.refcount[off])

    def _reclaim_block(self, blk: _Block) -> None:
        """Entirely-free block -> return to the global heap (madvise)."""
        self._unlink(blk)
        del self.blocks[blk.block_id]
        self.stats["blocks_released"] += 1
        if self._release:
            self._release(blk.block_id)

    # -- introspection --------------------------------------------------------
    @property
    def allocated_pages(self) -> int:
        return sum(USABLE_PER_BLOCK - b.free_count
                   for b in self.blocks.values())

    @property
    def committed_blocks(self) -> int:
        return len(self.blocks)

    def free_list_blocks(self) -> List[int]:
        out, cur, seen = [], self.free_head, set()
        while cur is not None:
            assert cur not in seen, "free-list cycle"
            seen.add(cur)
            out.append(cur)
            cur = self.blocks[cur].next
        return out

    def check_invariants(self) -> None:
        """Structural invariants (used by the hypothesis property tests)."""
        for bid, blk in self.blocks.items():
            n_free = sum(int(blk.l2[w]).bit_count() for w in range(L2_WORDS))
            assert n_free == blk.free_count, (bid, n_free, blk.free_count)
            for w in range(L2_WORDS):
                has_free = int(blk.l2[w]) != 0
                l1_bit = bool((int(blk.l1) >> w) & 1)
                assert l1_bit == has_free, (bid, w)
            assert not blk.is_free(0), "control page must stay reserved"
            for off in range(PAGES_PER_BLOCK):
                if blk.is_free(off):
                    assert blk.refcount[off] == 0, (bid, off)
            assert 0 < blk.free_count <= USABLE_PER_BLOCK or \
                bid not in self.free_list_blocks()
        in_list = self.free_list_blocks()
        assert len(in_list) == len(set(in_list))
        for bid in in_list:
            assert self.blocks[bid].free_count > 0
        for bid, blk in self.blocks.items():
            if blk.free_count > 0:
                assert bid in in_list, f"block {bid} has free pages, not listed"

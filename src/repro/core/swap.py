"""Per-sandbox swap files (§3.4, Fig. 5).

Each instance owns two files, never shared between sandboxes (security,
§3.4) and deleted at termination:

  * :class:`SwapFile` — the page-fault file.  Units are written individually
    (hash-table of offsets, like the Swapping Mgr's de-dup table) and read
    back **one ``pread`` at a time** — the random-read path.
  * :class:`ReapFile` — the REAP file.  The recorded working set is written
    with one contiguous ``pwritev``-style write and read back with a single
    sequential ``preadv``-style read over the saved scatter io-vectors.

Real file descriptors and real disk IO: the benchmarks measure the actual
random-vs-sequential asymmetry of this host's storage.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np


@dataclass
class _Extent:
    offset: int
    nbytes: int
    dtype: str
    shape: Tuple[int, ...]


class _FileBase:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o600)
        self.extents: Dict[Hashable, _Extent] = {}
        self._append_at = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.reads = 0
        self.writes = 0

    def delete(self) -> None:
        """Sandbox termination: close and unlink (§3.4)."""
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None
        if os.path.exists(self.path):
            os.unlink(self.path)
        self.extents.clear()

    def __contains__(self, key) -> bool:
        return key in self.extents

    @property
    def file_bytes(self) -> int:
        return self._append_at


class SwapFile(_FileBase):
    """Page-fault swap file: per-unit writes, random per-unit reads."""

    def write_unit(self, key: Hashable, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        buf = arr.tobytes()
        ext = self.extents.get(key)
        if ext is None or ext.nbytes < len(buf):
            ext = _Extent(self._append_at, len(buf), str(arr.dtype), arr.shape)
            self._append_at += len(buf)
        else:
            ext = _Extent(ext.offset, len(buf), str(arr.dtype), arr.shape)
        os.pwrite(self.fd, buf, ext.offset)
        self.extents[key] = ext
        self.bytes_written += len(buf)
        self.writes += 1

    def write_units(self, items: Sequence[Tuple[Hashable, np.ndarray]]) -> None:
        for k, a in items:
            self.write_unit(k, a)

    def read_unit(self, key: Hashable) -> np.ndarray:
        """One random read — the page-fault swap-in path."""
        ext = self.extents[key]
        buf = os.pread(self.fd, ext.nbytes, ext.offset)
        self.bytes_read += ext.nbytes
        self.reads += 1
        return np.frombuffer(buf, ext.dtype).reshape(ext.shape).copy()


class ReapFile(_FileBase):
    """REAP file: one batch-sequential write, one batch-sequential read."""

    def write_batch(self, items: Sequence[Tuple[Hashable, np.ndarray]]) -> None:
        """pwritev analogue: the scatter io-vectors are concatenated and
        written with a single contiguous write starting at offset 0."""
        self.extents.clear()
        bufs: List[bytes] = []
        off = 0
        for key, arr in items:
            arr = np.ascontiguousarray(arr)
            b = arr.tobytes()
            self.extents[key] = _Extent(off, len(b), str(arr.dtype), arr.shape)
            bufs.append(b)
            off += len(b)
        blob = b"".join(bufs)
        os.pwrite(self.fd, blob, 0)
        self._append_at = len(blob)
        self.bytes_written += len(blob)
        self.writes += 1

    def read_unit(self, key: Hashable) -> np.ndarray:
        """Random single-extent read (pagefault-mode access to a REAP file)."""
        ext = self.extents[key]
        buf = os.pread(self.fd, ext.nbytes, ext.offset)
        self.bytes_read += ext.nbytes
        self.reads += 1
        return np.frombuffer(buf, ext.dtype).reshape(ext.shape).copy()

    def read_batch(self) -> Dict[Hashable, np.ndarray]:
        """preadv analogue: one sequential read of the whole extent."""
        blob = os.pread(self.fd, self._append_at, 0)
        self.bytes_read += len(blob)
        self.reads += 1
        mv = memoryview(blob)                 # zero-copy scatter
        out = {}
        for key, ext in self.extents.items():
            out[key] = np.frombuffer(
                mv[ext.offset:ext.offset + ext.nbytes],
                ext.dtype).reshape(ext.shape)
        return out

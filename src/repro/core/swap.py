"""Per-sandbox swap files (§3.4, Fig. 5).

Each instance owns two files, never shared between sandboxes (security,
§3.4) and deleted at termination:

  * :class:`SwapFile` — the page-fault file.  Units are written individually
    (hash-table of offsets, like the Swapping Mgr's de-dup table) and read
    back **one ``pread`` at a time** — the random-read path.
  * :class:`ReapFile` — the REAP file.  The recorded working set is written
    with one contiguous ``pwritev`` and read back with a single sequential
    ``preadv`` over the saved scatter io-vectors.

Both classes also serve *vectored* batch reads (:meth:`_FileBase.read_units`):
the fault set is extent-sorted, adjacent extents are merged into runs, and
each run is one ``preadv`` syscall — this is what turns a wake storm's
hundreds of random faults into a handful of sequential disk passes.

Real file descriptors and real disk IO: the benchmarks measure the actual
random-vs-sequential asymmetry of this host's storage.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np

#: max io-vectors per preadv/pwritev call (POSIX guarantees >= 16; Linux 1024)
IOV_MAX = 1024

_HAVE_PREADV = hasattr(os, "preadv")
_HAVE_PWRITEV = hasattr(os, "pwritev")


def _preadv_full(fd, bufs, offset: int) -> int:
    """``preadv`` that retries short reads (Linux caps one read at ~2 GiB;
    signals can also truncate) until every buffer is filled.  Returns the
    number of syscalls issued; raises ``EOFError`` on a true EOF."""
    views = [memoryview(b) for b in bufs]
    want = sum(len(v) for v in views)
    done, calls = 0, 0
    while done < want:
        pending, skip = [], done
        for v in views:
            if skip >= len(v):
                skip -= len(v)
                continue
            pending.append(v[skip:] if skip else v)
            skip = 0
        n = os.preadv(fd, pending, offset + done)
        calls += 1
        if n <= 0:                         # pragma: no cover - EOF/IO error
            raise EOFError(f"preadv: short read at offset {offset + done}")
        done += n
    return calls


@dataclass
class _Extent:
    offset: int
    nbytes: int
    dtype: str
    shape: Tuple[int, ...]


@dataclass
class WriteReceipt:
    """What one batch of unit writes actually did to the disk tier.

    ``logical_bytes`` is what a verbatim per-sandbox layout would store;
    the other fields break that down for content-addressed backends
    (``SwapStore``).  Plain files store everything verbatim, so for them
    ``stored_bytes == logical_bytes``.
    """
    logical_bytes: int = 0       # raw bytes the caller asked to persist
    stored_bytes: int = 0        # new on-disk bytes this write added
    dedup_bytes: int = 0         # raw bytes satisfied by existing segments
    elided_bytes: int = 0        # raw bytes elided to constant-fill metadata

    def __iadd__(self, o: "WriteReceipt") -> "WriteReceipt":
        self.logical_bytes += o.logical_bytes
        self.stored_bytes += o.stored_bytes
        self.dedup_bytes += o.dedup_bytes
        self.elided_bytes += o.elided_bytes
        return self


def read_extents(fd, extents: Sequence[Tuple[int, int]]
                 ) -> Tuple[List[bytearray], int]:
    """Vectored read of ``(offset, nbytes)`` extents pre-sorted by offset:
    adjacent extents merge into runs and each run is one ``preadv``
    (chunked at ``IOV_MAX`` io-vectors).  Returns the filled buffers in
    input order plus the syscall count — shared by the per-sandbox files
    and the content-addressed ``SwapStore`` segment reads."""
    bufs: List[bytearray] = []
    run: List[bytearray] = []
    run_start = run_end = None
    calls = 0

    def flush():
        nonlocal calls
        if not run:
            return
        if _HAVE_PREADV:
            pos, i = run_start, 0
            while i < len(run):
                chunk = run[i:i + IOV_MAX]
                calls += _preadv_full(fd, chunk, pos)
                pos += sum(len(b) for b in chunk)
                i += IOV_MAX
        else:                              # pragma: no cover - non-POSIX
            pos = run_start
            for buf in run:
                buf[:] = os.pread(fd, len(buf), pos)
                calls += 1
                pos += len(buf)
        run.clear()

    for off, n in extents:
        if run_end is not None and off != run_end:
            flush()
            run_start = None
        if run_start is None:
            run_start = off
        buf = bytearray(n)
        run.append(buf)
        bufs.append(buf)
        run_end = off + n
    flush()
    return bufs, calls


class _FileBase:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o600)
        self.extents: Dict[Hashable, _Extent] = {}
        self._append_at = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.reads = 0
        self.writes = 0

    def delete(self) -> None:
        """Sandbox termination: close and unlink (§3.4).  Any ``.tmp``
        left by a write that crashed pre-commit goes with it."""
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None
        for p in (self.path, self.path + ".tmp"):
            if os.path.exists(p):
                os.unlink(p)
        self.extents.clear()

    def __contains__(self, key) -> bool:
        return key in self.extents

    @property
    def file_bytes(self) -> int:
        return self._append_at

    # ------------------------------------------------------------- vectored
    def read_units(self, keys: Sequence[Hashable]
                   ) -> Dict[Hashable, np.ndarray]:
        """Vectored batch read of a fault set.

        Extents are sorted by file offset and adjacent extents are merged
        into runs; each run is served by one ``preadv`` (chunked at
        ``IOV_MAX`` io-vectors).  ``self.reads`` counts *syscalls*, so the
        per-unit vs vectored asymmetry is directly observable.
        """
        exts = sorted(((k, self.extents[k]) for k in keys),
                      key=lambda kv: kv[1].offset)
        bufs, calls = read_extents(self.fd,
                                   [(e.offset, e.nbytes) for _, e in exts])
        self.reads += calls
        out: Dict[Hashable, np.ndarray] = {}
        for (key, ext), buf in zip(exts, bufs):
            self.bytes_read += ext.nbytes
            out[key] = np.frombuffer(buf, ext.dtype).reshape(ext.shape).copy()
        return out

    def read_units_iter(self, keys: Sequence[Hashable],
                        chunk_bytes: int = 1 << 20):
        """Streaming variant of :meth:`read_units`: yields ``{key: array}``
        dicts of ~``chunk_bytes`` each, one vectored batch read per chunk.
        Callers overlap downstream work (install, decompress) with the next
        chunk's IO instead of materializing the whole fault set at once —
        the building block of the streamed wake pipeline."""
        batch: List[Hashable] = []
        pending = 0
        for k in keys:
            batch.append(k)
            pending += self.extents[k].nbytes
            if pending >= chunk_bytes:
                yield self.read_units(batch)
                batch, pending = [], 0
        if batch:
            yield self.read_units(batch)


class SwapFile(_FileBase):
    """Page-fault swap file: per-unit writes, random per-unit reads."""

    def write_unit(self, key: Hashable, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        buf = arr.tobytes()
        ext = self.extents.get(key)
        if ext is None or ext.nbytes < len(buf):
            ext = _Extent(self._append_at, len(buf), str(arr.dtype), arr.shape)
            self._append_at += len(buf)
        else:
            ext = _Extent(ext.offset, len(buf), str(arr.dtype), arr.shape)
        os.pwrite(self.fd, buf, ext.offset)
        self.extents[key] = ext
        self.bytes_written += len(buf)
        self.writes += 1

    def write_units(self, items: Sequence[Tuple[Hashable, np.ndarray]]
                    ) -> WriteReceipt:
        r = WriteReceipt()
        for k, a in items:
            self.write_unit(k, a)
            r.logical_bytes += a.nbytes
            r.stored_bytes += a.nbytes       # verbatim: no dedup/elision
        return r

    def read_unit(self, key: Hashable) -> np.ndarray:
        """One random read — the page-fault swap-in path."""
        ext = self.extents[key]
        buf = os.pread(self.fd, ext.nbytes, ext.offset)
        self.bytes_read += ext.nbytes
        self.reads += 1
        return np.frombuffer(buf, ext.dtype).reshape(ext.shape).copy()


class ReapFile(_FileBase):
    """REAP file: one batch-sequential write, one batch-sequential read."""

    def write_batch(self, items: Sequence[Tuple[Hashable, np.ndarray]]) -> None:
        """One vectored sequential write (``pwritev``) of the scatter
        io-vectors, committed torn-write-safely.

        The blob is written to ``<path>.tmp`` and ``os.rename``d over the
        live file only once fully on disk — rename is atomic within a
        filesystem, so a crash mid-write leaves the *previous* REAP
        snapshot (file and extent table) fully intact instead of a
        half-written scatter that would feed garbage into the next wake.
        Extents are installed only after the rename for the same reason.
        The tmp file is truncated-by-creation so ``file_bytes`` always
        reflects the real on-disk footprint (a smaller rewrite must not
        leave stale trailing bytes)."""
        bufs: List[bytes] = []
        new_extents: Dict[Hashable, _Extent] = {}
        off = 0
        for key, arr in items:
            arr = np.ascontiguousarray(arr)
            b = arr.tobytes()
            new_extents[key] = _Extent(off, len(b), str(arr.dtype), arr.shape)
            bufs.append(b)
            off += len(b)
        tmp = self.path + ".tmp"
        tmp_fd = os.open(tmp, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o600)
        try:
            if bufs:
                if _HAVE_PWRITEV:
                    pos, i = 0, 0
                    while i < len(bufs):
                        chunk = bufs[i:i + IOV_MAX]
                        want = sum(len(b) for b in chunk)
                        n = os.pwritev(tmp_fd, chunk, pos)
                        if n != want:      # pragma: no cover - short write
                            os.pwrite(tmp_fd, b"".join(chunk)[n:], pos + n)
                        pos += want
                        i += IOV_MAX
                else:                      # pragma: no cover - non-POSIX
                    os.pwrite(tmp_fd, b"".join(bufs), 0)
                self.writes += 1
            os.rename(tmp, self.path)      # the commit point
        except BaseException:
            os.close(tmp_fd)
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        os.close(self.fd)
        self.fd = tmp_fd
        self.extents = new_extents
        self._append_at = off
        self.bytes_written += off

    def read_unit(self, key: Hashable) -> np.ndarray:
        """Random single-extent read (pagefault-mode access to a REAP file)."""
        ext = self.extents[key]
        buf = os.pread(self.fd, ext.nbytes, ext.offset)
        self.bytes_read += ext.nbytes
        self.reads += 1
        return np.frombuffer(buf, ext.dtype).reshape(ext.shape).copy()

    def read_batch(self) -> Dict[Hashable, np.ndarray]:
        """preadv analogue: one sequential read of the whole extent."""
        blob = os.pread(self.fd, self._append_at, 0)
        self.bytes_read += len(blob)
        self.reads += 1
        mv = memoryview(blob)                 # zero-copy scatter
        return {key: np.frombuffer(
                    mv[ext.offset:ext.offset + ext.nbytes],
                    ext.dtype).reshape(ext.shape)
                for key, ext in self.extents.items()}

"""Bridge: bitmap-pool paged KV cache -> Pallas paged_attention kernel.

On TPU the decode hot loop never gathers pages into a dense cache: the
``paged_attention`` kernel reads K/V pool pages through the page table
(grid-level indirection over the Bitmap Page Allocator's pages).  This
module builds the kernel's view of a :class:`PagedKVCache`:

  k_pages/v_pages : (Hkv, P_used, page_tokens, D) — compacted pool pages
  page_table      : (B, pages_per_seq) int32 into the compacted pages
  lengths         : (B,) int32

The CPU engine uses the dense-gather path (same math, same oracle); this
bridge + its equivalence test prove the kernel serves the identical
logical cache.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as pa_ops
from repro.serving.paged_kv import PagedKVCache


def kernel_view(kv: PagedKVCache, session_ids: Sequence[str], layer: int):
    """Build the kernel-layout arrays for one layer of a session batch."""
    cfg = kv.cfg
    if cfg.attention != "gqa":
        raise ValueError("paged_attention kernel serves GQA caches")
    Hkv, D, T = cfg.num_kv_heads, cfg.head_dim, kv.page_tokens

    phys_ids: List[int] = []
    index_of = {}
    rows = []
    for sid in session_ids:
        sess = kv.sessions[sid]
        row = []
        for pidx, pid in enumerate(sess.pages[layer]):
            if pid is None:
                # a shared-prefix slot can remap straight from the
                # registry (COW reattach, no disk IO); anything else is a
                # genuine swapped-out page the fault tier must restore
                pid = kv.ensure_prefix_slot(sid, layer, pidx)
            if pid is None:
                raise KeyError(("kv", sid, layer, "swapped"))
            if pid not in index_of:
                index_of[pid] = len(phys_ids)
                phys_ids.append(pid)
            row.append(index_of[pid])
        rows.append(row)
    pages_per_seq = max((len(r) for r in rows), default=1) or 1
    page_table = np.zeros((len(session_ids), pages_per_seq), np.int32)
    for b, row in enumerate(rows):
        page_table[b, :len(row)] = row

    P_used = max(len(phys_ids), 1)
    k_pages = np.zeros((Hkv, P_used, T, D), np.float32)
    v_pages = np.zeros((Hkv, P_used, T, D), np.float32)
    usable = T * kv.token_elems
    for j, pid in enumerate(phys_ids):
        phys = kv.pool._phys([pid])[0]
        page = kv.pool.data[phys][:usable].reshape(T, 2, Hkv, D)
        k_pages[:, j] = page[:, 0].transpose(1, 0, 2)
        v_pages[:, j] = page[:, 1].transpose(1, 0, 2)

    lengths = np.asarray([kv.sessions[s].num_tokens for s in session_ids],
                         np.int32)
    return (jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(page_table), jnp.asarray(lengths))


def paged_decode(kv: PagedKVCache, session_ids: Sequence[str], layer: int,
                 q, *, window: int = 0, interpret: bool = True):
    """q: (B, H, D) query for one layer -> (B, H, D) attention output,
    computed by the Pallas kernel directly over pool pages."""
    k_pages, v_pages, page_table, lengths = kernel_view(
        kv, session_ids, layer)
    return pa_ops.paged_decode_attention(
        q, k_pages, v_pages, page_table, lengths,
        window=window, interpret=interpret)

"""Paged KV/SSM cache over the shared page pool (the paper's guest memory).

The cache is the *anonymous application memory* of a model instance: KV
entries live in fixed-size pool pages managed by the Bitmap Page Allocator;
SSM/conv/cross-attention states are host-cache units riding the same swap
machinery.  Logical *keys* are stable across hibernation cycles (physical
page ids are not — pages are freed on deflate and re-allocated on inflate,
exactly like madvise'd memory being recommitted by the host on fault):

  ``("kv",  session_id, layer, page_idx)``  one pool page of KV tokens
  ``("kvh", session_id, layer, kind)``      a host unit (ssm state, conv,
                                            cross_k/v, MLA latent uses "kv")

Sessions model multi-turn serverless invocations: a *closed* session's pages
are "freed by the guest application but not yet returned to the host" — the
``trim()`` pass (deflation step 2) returns them to the shared pool.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass
class KVSession:
    session_id: str
    num_tokens: int = 0
    token_ids: List[int] = field(default_factory=list)
    #: pages[layer][i] = physical page id, or None while swapped out
    pages: List[List[Optional[int]]] = field(default_factory=list)
    #: host units: key -> array (None while swapped out)
    host_units: Dict[Tuple, Optional[np.ndarray]] = field(default_factory=dict)
    host_shapes: Dict[Tuple, Tuple] = field(default_factory=dict)
    closed: bool = False
    #: registry digest when the leading tokens map a shared prefix
    #: (:mod:`repro.core.prefix`); stable across hibernation cycles
    prefix_digest: Optional[bytes] = None
    #: tokens the shared prefix covers (<= num_tokens)
    prefix_tokens: int = 0
    #: True while the prefix slots map the registry's pages (cleared on
    #: deflate, restored by reattach)
    prefix_resident: bool = False


class PagedKVCache:
    """Per-instance paged cache.  ``token_elems`` is the per-layer flattened
    KV element count per token (2*Hkv*D for GQA, r+rd for MLA)."""

    def __init__(self, instance_id: str, cfg, pool, registry=None):
        self.instance_id = instance_id
        self.cfg = cfg
        self.pool = pool
        #: deployment prefix registry (``repro.core.prefix``) — None
        #: disables cross-tenant prefix adoption for this instance
        self.registry = registry
        if cfg.attention == "mla":
            self.token_elems = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        elif cfg.attention == "none":
            self.token_elems = 0
        else:
            self.token_elems = 2 * cfg.num_kv_heads * cfg.head_dim
        # tokens per pool page (pool page size is global, shared by tenants)
        self.page_tokens = max(1, pool.page_elems // max(self.token_elems, 1)) \
            if self.token_elems else 0
        self.sessions: Dict[str, KVSession] = {}
        self.dropped = False                 # True while deflated

    # ------------------------------------------------------------- sessions
    def new_session(self, session_id: str) -> KVSession:
        if session_id in self.sessions:
            raise KeyError(f"session {session_id} exists")
        s = KVSession(session_id,
                      pages=[[] for _ in range(self.cfg.num_layers)])
        self.sessions[session_id] = s
        return s

    def close_session(self, session_id: str) -> None:
        """Guest 'free': pages stay committed until trim() reclaims them."""
        self.sessions[session_id].closed = True

    def fork_session(self, src_id: str, dst_id: str) -> KVSession:
        """COW prefix sharing: the new session references the same physical
        pages; the allocator refcounts them (paper's clone/COW analogue)."""
        src = self.sessions[src_id]
        dst = self.new_session(dst_id)
        dst.num_tokens = src.num_tokens
        dst.token_ids = list(src.token_ids)
        dst.pages = [list(layer) for layer in src.pages]
        shared = [p for layer in src.pages for p in layer if p is not None]
        self.pool.share(shared, self.instance_id)
        for k, v in src.host_units.items():
            nk = (k[0], dst_id) + k[2:]
            dst.host_units[nk] = None if v is None else v.copy()
            dst.host_shapes[nk] = src.host_shapes[k]
        if self.registry is not None and src.prefix_digest is not None:
            # the fork maps the same registry pages: it is a sharer too
            dst.prefix_digest = src.prefix_digest
            dst.prefix_tokens = src.prefix_tokens
            dst.prefix_resident = src.prefix_resident
            e = self.registry.get(src.prefix_digest)
            if e is not None:
                e.sharers.add((self.instance_id, dst_id))
                if dst.prefix_resident:
                    e.resident_sharers.add((self.instance_id, dst_id))
        return dst

    # ------------------------------------------------------------- writes
    def _n_pages(self, tokens: int) -> int:
        return -(-tokens // self.page_tokens) if self.token_elems else 0

    def write_tokens(self, session_id: str, layer: int,
                     data: np.ndarray, start_tok: int) -> List[Tuple]:
        """Write ``data`` ((T, token_elems)) at token offset ``start_tok``
        for one layer.  Allocates pages as needed.  Returns touched keys."""
        s = self.sessions[session_id]
        T = data.shape[0]
        data = np.asarray(data, self.pool.dtype).reshape(T, self.token_elems)
        touched = []
        t = 0
        while t < T:
            tok = start_tok + t
            pidx, off = divmod(tok, self.page_tokens)
            while len(s.pages[layer]) <= pidx:
                s.pages[layer].append(self.pool.alloc(1, self.instance_id)[0])
            pid = s.pages[layer][pidx]
            if pid is None:                      # swapped-out page: fault first
                raise KeyError(("kv", session_id, layer, pidx))
            if self.pool.refcount(pid) > 1:
                # COW write fault: the page is shared (prefix registry or
                # a forked sibling) — never overwrite, break to a private
                # copy first so every other sharer stays bit-exact
                pid = self.pool.break_cow(pid, self.instance_id)
                s.pages[layer][pidx] = pid
            n = min(self.page_tokens - off, T - t)
            phys = self.pool._phys([pid])[0]
            usable = self.page_tokens * self.token_elems
            page_view = self.pool.data[phys][:usable].reshape(
                self.page_tokens, self.token_elems)
            page_view[off:off + n] = data[t:t + n]
            touched.append(("kv", session_id, layer, pidx))
            t += n
        return touched

    def read_tokens(self, session_id: str, layer: int, n_tokens: int
                    ) -> np.ndarray:
        """Gather the first ``n_tokens`` of a layer into a dense array."""
        s = self.sessions[session_id]
        out = np.zeros((n_tokens, self.token_elems), self.pool.dtype)
        t = 0
        while t < n_tokens:
            pidx, off = divmod(t, self.page_tokens)
            pid = s.pages[layer][pidx]
            if pid is None:
                raise KeyError(("kv", session_id, layer, pidx))
            n = min(self.page_tokens - off, n_tokens - t)
            phys = self.pool._phys([pid])[0]
            usable = self.page_tokens * self.token_elems
            page = self.pool.data[phys][:usable].reshape(
                self.page_tokens, self.token_elems)
            out[t:t + n] = page[off:off + n]
            t += n
        return out

    def set_host_unit(self, session_id: str, layer, kind: str,
                      arr: np.ndarray) -> Tuple:
        s = self.sessions[session_id]
        key = ("kvh", session_id, layer, kind)
        s.host_units[key] = np.asarray(arr)
        s.host_shapes[key] = arr.shape
        return key

    def get_host_unit(self, session_id: str, layer, kind: str) -> np.ndarray:
        s = self.sessions[session_id]
        key = ("kvh", session_id, layer, kind)
        arr = s.host_units[key]
        if arr is None:
            raise KeyError(key)
        return arr

    def keys_for(self, session_id: str, window_tokens: Optional[int] = None
                 ) -> List[Tuple]:
        """All logical keys a request on this session will touch (pages in
        the attention window + every host unit) — the fault/record set."""
        s = self.sessions[session_id]
        keys: List[Tuple] = list(s.host_units)
        if self.token_elems:
            first_tok = 0
            if window_tokens is not None:
                first_tok = max(0, s.num_tokens - window_tokens)
            p0 = first_tok // self.page_tokens
            for layer in range(self.cfg.num_layers):
                for pidx in range(p0, len(s.pages[layer])):
                    keys.append(("kv", session_id, layer, pidx))
        return keys

    def nonresident_keys(self, keys: Sequence[Tuple]) -> List[Tuple]:
        out = []
        for k in keys:
            s = self.sessions.get(k[1])
            if s is None:
                continue
            if k[0] == "kv":
                if s.pages[k[2]][k[3]] is None:
                    out.append(k)
            elif s.host_units.get(k) is None:
                out.append(k)
        return out

    # ------------------------------------------------------------- prefix
    def _prefix_entry_pages(self, s: KVSession):
        """The registry entry's resident page table for this session's
        prefix, or None (no registry / no prefix / entry spilled)."""
        if self.registry is None or s.prefix_digest is None:
            return None
        e = self.registry.get(s.prefix_digest)
        return None if e is None else e.pages

    def is_prefix_slot(self, s: KVSession, layer: int, pidx: int) -> bool:
        """True when the slot still maps the registry's own page (COW-
        broken slots hold a private copy and are the tenant's to swap)."""
        ep = self._prefix_entry_pages(s)
        return (ep is not None and layer < len(ep)
                and pidx < len(ep[layer])
                and s.pages[layer][pidx] == ep[layer][pidx])

    def _prefix_page_count(self, s: KVSession) -> int:
        """Pages per layer the session's prefix spans."""
        return self._n_pages(s.prefix_tokens) if s.prefix_digest else 0

    def export_prefix_page(self, pid: int, pidx: int,
                           num_tokens: int) -> np.ndarray:
        """Registry write-through export: one page with the same zero-tail
        contract as :meth:`_export_page`, bounded by the *prefix* token
        count (not a session's) so identical prefixes hash identically."""
        phys = self.pool._phys([pid])[0]
        data = self.pool.data[phys].copy()
        used = min(max(num_tokens - pidx * self.page_tokens, 0),
                   self.page_tokens) * self.token_elems
        data[used:] = 0
        return data

    def prefix_missing_keys(self) -> List[Tuple]:
        """Not-Present page slots inside each session's prefix range —
        what a wake must either restore from swap (COW-broken copies) or
        reattach from the registry."""
        keys: List[Tuple] = []
        for sid, s in self.sessions.items():
            np_pages = self._prefix_page_count(s)
            if not np_pages:
                continue
            for layer in range(len(s.pages)):
                for pidx in range(min(np_pages, len(s.pages[layer]))):
                    if s.pages[layer][pidx] is None:
                        keys.append(("kv", sid, layer, pidx))
        return keys

    def ensure_prefix_slot(self, session_id: str, layer: int,
                           pidx: int) -> Optional[int]:
        """Last-chance remap for the compute path: re-share a Not-Present
        prefix slot from the registry, but ONLY when the slot provably
        never COW-broke (fully-covered page, or nothing was ever written
        past the prefix) — a broken slot's bytes live in the swap tier and
        must fault in from there.  Returns the page id or None."""
        s = self.sessions[session_id]
        if self.registry is None or s.prefix_digest is None or \
                pidx >= self._prefix_page_count(s):
            return None
        fully_covered = (pidx + 1) * self.page_tokens <= s.prefix_tokens
        if not (fully_covered or s.num_tokens == s.prefix_tokens):
            return None
        self.registry.reattach(self, session_id, [(layer, pidx)])
        return s.pages[layer][pidx]

    # ------------------------------------------------------------- hibernate
    def trim(self) -> int:
        """Deflation step 2: return closed sessions' pages to the pool."""
        n = 0
        for sid in [s for s, v in self.sessions.items() if v.closed]:
            s = self.sessions.pop(sid)
            pages = [p for layer in s.pages for p in layer if p is not None]
            n += len(pages)
            self.pool.free(pages, self.instance_id)
            if self.registry is not None and s.prefix_digest is not None:
                self.registry.release_sharer(s.prefix_digest,
                                             self.instance_id, sid)
        return n

    def _export_page(self, s: KVSession, pid: int, pidx: int) -> np.ndarray:
        """One page's swap-out copy.  The region beyond its written
        tokens is allocator garbage; it is zeroed so identical-content
        pages hash identically across sessions and tenants — this is
        what lets KV pages dedup (and half-empty tail pages
        constant-elide) in the content-addressed SwapStore."""
        phys = self.pool._phys([pid])[0]
        data = self.pool.data[phys].copy()
        used = min(max(s.num_tokens - pidx * self.page_tokens, 0),
                   self.page_tokens) * self.token_elems
        data[used:] = 0
        return data

    def export_items(self, working_set: frozenset
                     ) -> Tuple[List[Tuple[Tuple, np.ndarray]],
                                List[Tuple[Tuple, np.ndarray]]]:
        """Partition resident cache units into (reap, swap) item lists
        (pages exported via :meth:`_export_page`'s zero-tail contract)."""
        reap, swap = [], []
        for sid, s in self.sessions.items():
            for layer in range(len(s.pages)):
                for pidx, pid in enumerate(s.pages[layer]):
                    if pid is None:
                        continue
                    if self.is_prefix_slot(s, layer, pidx):
                        # registry-backed page: already content-addressed
                        # at registration; the wake reattaches by digest —
                        # exporting it would double-swap another tenant's
                        # (and the registry's) live mapping
                        continue
                    key = ("kv", sid, layer, pidx)
                    data = self._export_page(s, pid, pidx)
                    (reap if key in working_set else swap).append((key, data))
            for key, arr in s.host_units.items():
                if arr is None:
                    continue
                (reap if key in working_set else swap).append((key, arr))
        return reap, swap

    def resident_keys(self) -> List[Tuple]:
        """Every logical key currently backed by memory (pool pages with a
        physical id + host units holding an array) — the partial-deflate
        victim candidate set."""
        keys: List[Tuple] = []
        for sid, s in self.sessions.items():
            for layer in range(len(s.pages)):
                for pidx, pid in enumerate(s.pages[layer]):
                    if pid is not None and \
                            not self.is_prefix_slot(s, layer, pidx):
                        keys.append(("kv", sid, layer, pidx))
            keys += [k for k, a in s.host_units.items() if a is not None]
        return keys

    def key_nbytes(self, key: Tuple) -> int:
        """Bytes one logical key pins in memory."""
        if key[0] == "kv":
            return self.pool.page_elems * np.dtype(self.pool.dtype).itemsize
        s = self.sessions.get(key[1])
        if s is None:
            return 0
        arr = s.host_units.get(key)
        if arr is not None:
            return arr.nbytes
        shape = s.host_shapes.get(key)
        return int(np.prod(shape)) * 4 if shape else 0

    def export_keys(self, keys: Sequence[Tuple]
                    ) -> List[Tuple[Tuple, np.ndarray]]:
        """Materialize specific resident keys as (key, data) items via
        :meth:`_export_page` (zero-tail dedup contract) — the
        partial-deflate victim export."""
        items: List[Tuple[Tuple, np.ndarray]] = []
        for key in keys:
            s = self.sessions.get(key[1])
            if s is None:
                continue
            if key[0] == "kv":
                _, sid, layer, pidx = key
                if layer >= len(s.pages) or pidx >= len(s.pages[layer]):
                    continue
                pid = s.pages[layer][pidx]
                if pid is None or self.is_prefix_slot(s, layer, pidx):
                    continue
                items.append((key, self._export_page(s, pid, pidx)))
            elif key[0] == "kvh":
                arr = s.host_units.get(key)
                if arr is not None:
                    items.append((key, arr))
        return items

    def nonresident_logical_keys(self) -> List[Tuple]:
        """Inverse of :meth:`resident_keys`: logical keys whose backing
        is swapped out (Not-Present page-table slots, host units holding
        None) — what a rung-aware wake must consider restoring."""
        keys: List[Tuple] = []
        for sid, s in self.sessions.items():
            for layer in range(len(s.pages)):
                for pidx, pid in enumerate(s.pages[layer]):
                    if pid is None:
                        keys.append(("kv", sid, layer, pidx))
            keys += [k for k, a in s.host_units.items() if a is None]
        return keys

    def drop_keys(self, keys: Sequence[Tuple]) -> int:
        """Free the physical backing of specific keys (partial deflate's
        madvise): pool pages return to the allocator, page-table slots go
        Not-Present, host units drop their arrays.  Returns pages freed."""
        n = 0
        for key in keys:
            s = self.sessions.get(key[1])
            if s is None:
                continue
            if key[0] == "kv":
                _, sid, layer, pidx = key
                if layer >= len(s.pages) or pidx >= len(s.pages[layer]):
                    continue
                pid = s.pages[layer][pidx]
                if pid is not None:
                    was_prefix = self.is_prefix_slot(s, layer, pidx)
                    self.pool.free([pid], self.instance_id)
                    s.pages[layer][pidx] = None
                    n += 1
                    if was_prefix and s.prefix_resident:
                        # partially detached counts as detached: the
                        # registry must not treat this sharer as pinning
                        # the resident copy anymore
                        s.prefix_resident = False
                        self.registry.note_detach(
                            s.prefix_digest, self.instance_id, sid)
            elif key[0] == "kvh" and s.host_units.get(key) is not None:
                s.host_units[key] = None
        return n

    def drop_pages(self) -> int:
        """Deflation step 3 tail: free every physical page (madvise) but keep
        the logical page tables — the 'Not-Present' page-table entries."""
        n = 0
        for sid, s in self.sessions.items():
            for layer in range(len(s.pages)):
                for pidx, pid in enumerate(s.pages[layer]):
                    if pid is not None:
                        self.pool.free([pid], self.instance_id)
                        s.pages[layer][pidx] = None
                        n += 1
            for key in s.host_units:
                s.host_units[key] = None
            if self.registry is not None and s.prefix_digest is not None \
                    and s.prefix_resident:
                # the session still *logically* maps the prefix (it will
                # reattach by digest on wake); only the resident pin drops
                s.prefix_resident = False
                self.registry.note_detach(s.prefix_digest,
                                          self.instance_id, sid)
        self.dropped = True
        return n

    def apply_prefetch(self, data: Dict[Hashable, np.ndarray]) -> int:
        """Install a batch of swapped-in units (REAP batch read)."""
        return self.install_batch(
            [(k, a) for k, a in data.items() if k[0] in ("kv", "kvh")],
            mark=True)

    def install_batch(self, items: Sequence[Tuple[Tuple, np.ndarray]],
                      mark: bool = True) -> int:
        """Install a batch of swapped-in units in ONE pool scatter.

        Pool pages are collected (allocating physical pages for keys whose
        slots are still Not-Present) and written with a single
        :meth:`PagePool.scatter` — the ``page_copy.scatter_pages`` path,
        one scatter per wake-pipeline chunk instead of a per-page
        ``_set`` copy.  Host units install individually.  Keys of closed/
        trimmed sessions are skipped (a streamed wake may outlive them),
        and so are keys that are ALREADY resident: concurrent installers
        (streamer / demand / lookahead) are idempotent, and a stale
        background install must never clobber a page the engine has since
        faulted in and written fresh tokens to.  Returns bytes installed."""
        pages: List[int] = []
        rows: List[np.ndarray] = []
        n = 0
        for key, arr in items:
            s = self.sessions.get(key[1])
            if s is None:
                continue
            if key[0] == "kv":
                _, _sid, layer, pidx = key
                if layer >= len(s.pages) or pidx >= len(s.pages[layer]):
                    continue
                if s.pages[layer][pidx] is not None:
                    continue                   # resident: never overwrite
                s.pages[layer][pidx] = \
                    self.pool.alloc(1, self.instance_id)[0]
                pages.append(s.pages[layer][pidx])
                rows.append(np.asarray(arr).reshape(-1))
                n += arr.nbytes
            elif key[0] == "kvh" and key in s.host_shapes \
                    and s.host_units.get(key) is None:
                s.host_units[key] = np.asarray(arr).reshape(
                    s.host_shapes[key])
                n += arr.nbytes
        if pages:
            self.pool.scatter(pages, np.stack(rows))
        if mark and n:
            self.dropped = False
        return n

    def fault_in(self, keys: Sequence[Tuple], swap_file, reap_file) -> int:
        """Fault path: the key set is coalesced into one vectored batch
        read per file (extent-sorted, adjacent extents merged).

        Keys in no swap tier but inside a session's shared-prefix range
        remap from the registry instead (COW reattach — the prefix was
        never exported, its bytes live as registry pages or CAS segments).
        The swap tiers are consulted FIRST: a COW-broken prefix page's
        private copy is in the swap file, and restoring the pristine
        registry page there would clobber the session's divergent bytes.
        """
        swap_keys, reap_keys = [], []
        prefix_coords: Dict[str, List[Tuple[int, int]]] = {}
        for key in keys:
            if key in swap_file:
                swap_keys.append(key)
            elif key in reap_file.extents:
                reap_keys.append(key)
            elif key[0] == "kv" and self.registry is not None and \
                    (s := self.sessions.get(key[1])) is not None and \
                    s.prefix_digest is not None and \
                    key[3] < self._prefix_page_count(s):
                prefix_coords.setdefault(key[1], []).append(
                    (key[2], key[3]))
            else:
                raise KeyError(f"kv unit {key} not in any swap file")
        n = 0
        for f, ks in ((swap_file, swap_keys), (reap_file, reap_keys)):
            if not ks:
                continue
            # one vectored read + one pool scatter per file
            n += self.install_batch(list(f.read_units(ks).items()),
                                    mark=False)
        for sid, coords in prefix_coords.items():
            n += self.registry.reattach(self, sid, coords)
        return n

    # ------------------------------------------------------------- accounting
    def resident_page_count(self) -> int:
        return sum(1 for s in self.sessions.values()
                   for layer in s.pages for p in layer if p is not None)

    def host_bytes(self) -> int:
        return sum(a.nbytes for s in self.sessions.values()
                   for a in s.host_units.values() if a is not None)

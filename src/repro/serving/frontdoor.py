"""The serving front door: SLO-classed admission + streaming dispatch.

This is the policy half of the network gateway (the protocol half —
HTTP/1.1 chunked streaming and WebSocket framing — lives in
:mod:`repro.serving.gateway`).  It sits between network clients and the
dispatch surface (an :class:`~repro.serving.scheduler.AsyncPlatform`
for one node, or a :class:`~repro.cluster.router.ClusterRouter` for a
cluster) and owns three things:

* **SLO classes** — every request carries ``interactive`` or ``batch``.
  The class flows down the stack: the scheduler claims interactive work
  first and can cap batch queue depth separately, and the engine wakes
  a deflated tenant at low priority when only batch work wants it (a
  background job must not steal double-buffered wake bandwidth from an
  interactive tenant on the same store).
* **Bounded queues + honest backpressure** — admission is checked here
  (session caps) and at the platform (per-tenant queue depth).  A
  rejection is a :class:`Backpressure` carrying ``retry_after_s``
  derived from the governor's learned wake costs and the measured
  service rate — the gateway surfaces it as ``429 Retry-After: n``.
  When the node is under memory pressure (the governor is actively
  deflating) batch requests to not-yet-woken tenants are shed first:
  waking a tenant the governor would immediately re-deflate is the
  ping-pong the deflation ladder exists to avoid.
* **Token streams** — :class:`TokenStream` bridges the engine's
  ``on_token`` callback (worker thread) to a consumer (gateway event
  loop or client thread).  The first token fires when prefill completes,
  which on a woken tenant is as soon as the wake pipeline's critical
  prefix is resident — streaming TTFT tracks the wake path, not full
  inflate.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.state import RUNG_OF, Rung
from repro.serving.engine import (SLO_BATCH, SLO_INTERACTIVE, NodeDownError,
                                  Request)
from repro.serving.scheduler import AdmissionError

_END = object()


class Backpressure(RuntimeError):
    """The front door refused the request; retry after ``retry_after_s``."""

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = max(0.05, float(retry_after_s))


@dataclass
class FrontDoorPolicy:
    """Gateway admission knobs: session caps, batch shedding, and
    crash-redispatch behaviour."""

    #: gateway-wide cap on concurrently open streams
    max_sessions: int = 256
    #: per-tenant cap on concurrently open streams
    max_sessions_per_tenant: int = 32
    #: at most this fraction of max_sessions may be batch-SLO streams
    batch_share: float = 0.5
    #: floor for the Retry-After hint (seconds)
    min_retry_after_s: float = 0.25
    #: shed batch requests to deflated tenants while the governor is
    #: under pressure (deflating faster than it wakes)
    shed_batch_under_pressure: bool = True
    #: shed batch requests to a tenant the traffic forecaster has
    #: flagged as mid flash-crowd once the gateway is busier than
    #: ``burst_session_share`` — the reserved slots absorb the burst's
    #: interactive leading edge instead of background work
    shed_batch_during_burst: bool = True
    #: session-occupancy fraction above which a flash-crowd tenant's
    #: batch work is shed (only with a forecaster configured)
    burst_session_share: float = 0.5
    #: how many times a request killed by a node crash is re-dispatched
    #: (the cluster router re-places the tenant on a survivor); the
    #: stream dedups re-played tokens so the client never sees a repeat
    redispatch_attempts: int = 1
    #: completed idempotency keys remembered for replay (LRU bound)
    idempotency_cache: int = 1024


class TokenStream:
    """One streaming response: a thread-safe token queue with latency
    stamps.

    The engine worker pushes via :meth:`push` (wired as ``Request.on_token``)
    and finishes via :meth:`finish`; a consumer either iterates
    (blocking, client threads) or installs a ``waker`` callback and
    drains with :meth:`drain_nowait` (asyncio bridge — the waker is
    called from the worker thread, typically
    ``loop.call_soon_threadsafe``).

    A stream survives node crashes: when the front door re-dispatches
    the request (same idempotency key, surviving node) it calls
    :meth:`new_attempt`, and :meth:`push` drops the re-played prefix —
    the deterministic engine regenerates the same tokens, and the
    client sees each position exactly once."""

    def __init__(self, instance_id: str, session_id: str, slo: str):
        self.instance_id = instance_id
        self.session_id = session_id
        self.slo = slo
        self.submitted_at = time.monotonic()
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.response = None
        self.error: Optional[BaseException] = None
        #: tokens actually delivered to the consumer (across attempts)
        self.emitted = 0
        #: dispatch attempts (1 = never re-dispatched)
        self.attempts = 1
        self._attempt_pos = 0
        self._q: deque = deque()
        self._cv = threading.Condition()
        self.waker: Optional[Callable[[], None]] = None

    # ------------------------------------------------------------- producer
    def push(self, token: int) -> None:
        """Producer side (``Request.on_token``): append one token,
        stamping TTFT on the first and deduping re-played prefixes."""
        with self._cv:
            self._attempt_pos += 1
            if self._attempt_pos <= self.emitted:
                return                 # re-played prefix of a re-dispatch
            self.emitted += 1
            if self.first_token_at is None:
                self.first_token_at = time.monotonic()
            self._q.append(int(token))
            self._cv.notify_all()
        if self.waker is not None:
            self.waker()

    def new_attempt(self) -> None:
        """Reset the per-attempt position before a re-dispatch; already-
        emitted tokens will be deduped as the replacement node re-plays
        them."""
        with self._cv:
            self._attempt_pos = 0
            self.attempts += 1

    def finish(self, response=None,
               error: Optional[BaseException] = None) -> None:
        """Terminate the stream exactly once (with a response or a
        terminal error); consumers observe the end-of-stream marker."""
        with self._cv:
            if self.finished_at is not None:
                return
            self.finished_at = time.monotonic()
            self.response = response
            self.error = error
            self._q.append(_END)
            self._cv.notify_all()
        if self.waker is not None:
            self.waker()

    # ------------------------------------------------------------- consumer
    @property
    def done(self) -> bool:
        """True once :meth:`finish` ran (response or error is set)."""
        return self.finished_at is not None

    def ttft_s(self) -> Optional[float]:
        """Time to first token (None until one arrives)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    def next_token(self, timeout: Optional[float] = None):
        """Blocking pop: a token id, or ``None`` at end of stream (then
        the terminal error, if any, is raised)."""
        with self._cv:
            while not self._q:
                if not self._cv.wait(timeout):
                    raise TimeoutError("token stream stalled")
            tok = self._q.popleft()
        if tok is _END:
            if self.error is not None:
                raise self.error
            return None
        return tok

    def drain_nowait(self) -> List[int]:
        """Non-blocking: every queued token (the ``_END`` marker is left
        for ``done`` + emptiness checks by the async consumer)."""
        out = []
        with self._cv:
            while self._q and self._q[0] is not _END:
                out.append(self._q.popleft())
        return out

    def __iter__(self):
        while True:
            tok = self.next_token()
            if tok is None:
                return
            yield tok


class FrontDoor:
    """Admission + dispatch for streaming requests.

    ``target`` is anything with ``submit(Request) -> Future`` — a single
    node's :class:`~repro.serving.scheduler.AsyncPlatform` or a
    :class:`~repro.cluster.router.ClusterRouter` (which places unknown
    tenants cluster-wide).  ``arch_of`` registrations flow to the target
    so first-request admission resolves the model architecture — and,
    when the target's node holds a live zygote of that family, admits
    the unknown tenant by warm fork instead of a cold init (the
    platform's serve path and the router's ``place`` both try
    ``fork_instance`` first)."""

    def __init__(self, target, *,
                 policy: Optional[FrontDoorPolicy] = None):
        self.target = target
        self.policy = policy or FrontDoorPolicy()
        self._lock = threading.Lock()
        self._active: Dict[str, int] = {}      # tenant -> open streams
        self._active_total = 0
        self._active_batch = 0
        self.peak_sessions = 0
        self.accepted = 0
        self.rejected = 0
        self.completed = 0
        self.errors = 0
        self.redispatches = 0
        self.idem_hits = 0
        self.burst_sheds = 0
        #: idempotency_key -> live stream (in-flight dedupe) and a
        #: bounded LRU of finished streams (replay after completion)
        self._idem_inflight: Dict[str, TokenStream] = {}
        self._idem_done: "OrderedDict[str, TokenStream]" = OrderedDict()

    # ------------------------------------------------------------- helpers
    @property
    def arch_of(self) -> Dict[str, str]:
        """Tenant -> architecture registrations (owned by the target)."""
        return self.target.arch_of

    def register(self, instance_id: str, arch_key: str) -> None:
        """Bind a tenant to a model architecture for admission (cold
        start, or warm fork when a zygote of the family is live)."""
        self.target.arch_of.setdefault(instance_id, arch_key)

    def _platform_for(self, instance_id: str):
        # ClusterRouter: per-tenant node platform; AsyncPlatform: itself
        node_of = getattr(self.target, "node_of", None)
        if node_of is not None:
            node = node_of(instance_id)
            return node.platform if node is not None else None
        return self.target

    def _manager_for(self, instance_id: str):
        plat = self._platform_for(instance_id)
        return plat.engine.manager if plat is not None else None

    def retry_after_s(self, instance_id: str) -> float:
        """Honest backoff hint for a rejection: the tenant platform's
        wake-cost + queue estimate, floored at ``min_retry_after_s``."""
        plat = self._platform_for(instance_id)
        if plat is not None and hasattr(plat, "retry_after_s"):
            hint = plat.retry_after_s(instance_id)
        else:
            hint = 1.0
        return max(self.policy.min_retry_after_s, hint)

    # ------------------------------------------------------------- admission
    def _admit(self, instance_id: str, slo: str) -> None:
        pol = self.policy
        with self._lock:
            if self._active_total >= pol.max_sessions:
                self.rejected += 1
                raise Backpressure(
                    f"gateway at max_sessions={pol.max_sessions}",
                    self.retry_after_s(instance_id))
            if self._active.get(instance_id, 0) \
                    >= pol.max_sessions_per_tenant:
                self.rejected += 1
                raise Backpressure(
                    f"tenant {instance_id} at "
                    f"max_sessions_per_tenant={pol.max_sessions_per_tenant}",
                    self.retry_after_s(instance_id))
            if slo == SLO_BATCH and self._active_batch \
                    >= pol.batch_share * pol.max_sessions:
                self.rejected += 1
                raise Backpressure(
                    "batch share of sessions exhausted",
                    self.retry_after_s(instance_id))
        if slo == SLO_BATCH and pol.shed_batch_under_pressure:
            mgr = self._manager_for(instance_id)
            if mgr is not None:
                inst = mgr.instances.get(instance_id)
                deflated = inst is not None and \
                    RUNG_OF.get(inst.state, Rung.WARM) != Rung.WARM
                if deflated and mgr.governor.pressure_bytes() > 0:
                    # the node is deflating faster than it wakes: waking
                    # this tenant for background work would be undone by
                    # the governor's next pass — shed it instead
                    with self._lock:
                        self.rejected += 1
                    raise Backpressure(
                        f"node under memory pressure: batch wake of "
                        f"{instance_id} shed",
                        self.retry_after_s(instance_id))
        if slo == SLO_BATCH and pol.shed_batch_during_burst:
            mgr = self._manager_for(instance_id)
            fc = mgr.governor.forecaster if mgr is not None else None
            if fc is not None and \
                    self._active_total >= pol.burst_session_share * \
                    pol.max_sessions and \
                    fc.in_burst(instance_id, time.monotonic()):
                # a flash crowd is hitting this tenant and the gateway
                # is filling: keep the remaining session slots for the
                # burst's interactive leading edge — background work
                # retries after the spike
                with self._lock:
                    self.rejected += 1
                    self.burst_sheds += 1
                raise Backpressure(
                    f"tenant {instance_id} mid flash-crowd: batch "
                    "session shed", self.retry_after_s(instance_id))
        with self._lock:
            self._active_total += 1
            self._active_batch += 1 if slo == SLO_BATCH else 0
            self._active[instance_id] = \
                self._active.get(instance_id, 0) + 1
            self.peak_sessions = max(self.peak_sessions,
                                     self._active_total)
            self.accepted += 1

    def _release(self, instance_id: str, slo: str, ok: bool,
                 rejected: bool = False) -> None:
        with self._lock:
            self._active_total -= 1
            if slo == SLO_BATCH:
                self._active_batch -= 1
            n = self._active.get(instance_id, 0) - 1
            if n <= 0:
                self._active.pop(instance_id, None)
            else:
                self._active[instance_id] = n
            if ok:
                self.completed += 1
            elif rejected:
                self.rejected += 1
            else:
                self.errors += 1

    # ------------------------------------------------------------- dispatch
    def submit(self, instance_id: str, prompt, *, session_id: str,
               max_new_tokens: int = 8, slo: str = SLO_INTERACTIVE,
               arch_key: Optional[str] = None,
               close_session: bool = False,
               idempotency_key: Optional[str] = None) -> TokenStream:
        """Admit + dispatch one streaming request; returns immediately
        with a live :class:`TokenStream`.  Raises :class:`Backpressure`
        on rejection (never queues unboundedly).

        ``idempotency_key`` makes the call safe to repeat across client
        reconnects and node crashes: a key already in flight returns the
        live stream, a completed key replays the finished stream, and a
        request killed by :class:`NodeDownError` is re-dispatched (up to
        ``policy.redispatch_attempts`` times) against the re-homed
        tenant with re-played tokens deduped — the client never sees a
        token twice."""
        if slo not in (SLO_INTERACTIVE, SLO_BATCH):
            raise ValueError(f"unknown SLO class {slo!r}")
        if arch_key is not None:
            self.register(instance_id, arch_key)
        if instance_id not in self.target.arch_of:
            raise KeyError(f"tenant {instance_id} has no registered "
                           "architecture (pass arch_key once)")
        if idempotency_key is not None:
            with self._lock:
                hit = self._idem_inflight.get(idempotency_key)
                if hit is None:
                    hit = self._idem_done.get(idempotency_key)
                    if hit is not None:
                        self._idem_done.move_to_end(idempotency_key)
                if hit is not None:
                    self.idem_hits += 1
                    return hit
        self._admit(instance_id, slo)
        stream = TokenStream(instance_id, session_id, slo)
        if idempotency_key is not None:
            with self._lock:
                self._idem_inflight[idempotency_key] = stream

        def _make_req():
            return Request(
                instance_id=instance_id, session_id=session_id,
                prompt=np.asarray(prompt, np.int32),
                max_new_tokens=int(max_new_tokens),
                close_session=close_session, slo=slo,
                on_token=stream.push)

        def _settle(err, response=None, rejected=False):
            self._release(instance_id, slo, ok=err is None,
                          rejected=rejected)
            if idempotency_key is not None:
                with self._lock:
                    self._idem_inflight.pop(idempotency_key, None)
                    if err is None:
                        self._idem_done[idempotency_key] = stream
                        while len(self._idem_done) > \
                                self.policy.idempotency_cache:
                            self._idem_done.popitem(last=False)
            stream.finish(response=response, error=err)

        def _done(f):
            err = f.exception()
            if isinstance(err, NodeDownError) and \
                    stream.attempts <= self.policy.redispatch_attempts:
                # the tenant's node crashed mid-request; the router has
                # (or will) re-home the tenant from replicated segments
                # — re-play the identical request and dedup its tokens
                stream.new_attempt()
                with self._lock:
                    self.redispatches += 1
                try:
                    f2 = self.target.submit(_make_req())
                except BaseException as e2:     # noqa: BLE001 - surfaced
                    _settle(e2)
                    return
                f2.add_done_callback(_done)
                return
            if isinstance(err, AdmissionError):
                err = Backpressure(str(err),
                                   getattr(err, "retry_after_s", 1.0))
            if err is not None:
                _settle(err)
            else:
                _settle(None, response=f.result())

        try:
            fut = self.target.submit(_make_req())
        except AdmissionError as e:
            self._release(instance_id, slo, ok=False, rejected=True)
            if idempotency_key is not None:
                with self._lock:
                    self._idem_inflight.pop(idempotency_key, None)
            raise Backpressure(str(e), getattr(e, "retry_after_s", 1.0)) \
                from e
        except BaseException:
            self._release(instance_id, slo, ok=False)
            if idempotency_key is not None:
                with self._lock:
                    self._idem_inflight.pop(idempotency_key, None)
            raise
        if fut.done() and isinstance(fut.exception(), AdmissionError):
            # AsyncPlatform parks admission rejections on the future;
            # surface them synchronously so the gateway answers 429
            # instead of opening a stream that instantly errors
            err = fut.exception()
            self._release(instance_id, slo, ok=False, rejected=True)
            if idempotency_key is not None:
                with self._lock:
                    self._idem_inflight.pop(idempotency_key, None)
            raise Backpressure(str(err),
                               getattr(err, "retry_after_s", 1.0)) from err
        fut.add_done_callback(_done)
        return stream

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        """Gateway counters (admissions, sheds, redispatches, replay)."""
        with self._lock:
            return {
                "active_sessions": self._active_total,
                "active_batch": self._active_batch,
                "peak_sessions": self.peak_sessions,
                "accepted": self.accepted,
                "rejected": self.rejected,
                "completed": self.completed,
                "errors": self.errors,
                "tenants_active": len(self._active),
                "redispatches": self.redispatches,
                "idem_hits": self.idem_hits,
                "burst_sheds": self.burst_sheds,
                "idem_inflight": len(self._idem_inflight),
                "idem_cached": len(self._idem_done),
            }

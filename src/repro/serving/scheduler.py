"""Serverless platform control plane: event-driven, multi-tenant.

This is the control plane of Fig. 3, rebuilt around concurrency:

  * :class:`AsyncPlatform` — per-tenant request queues with admission
    control, a worker pool that serves *different* instances in parallel
    (per-instance locks keep each state machine race-free), and a
    background policy daemon that owns keep-alive deflation (④ SIGSTOP),
    memory-pressure handling, and predictive/anticipatory wakes (⑤
    SIGCONT).  ``submit`` returns a future; workers batch whatever is
    queued per tenant when they claim it (continuous batching).
  * :class:`Platform` — the original synchronous facade, kept as a thin
    compatibility shim: ``step()`` drains the queues inline and
    ``tick()`` runs one policy pass, with no threads involved.

Wake storms are deduplicated below the platform: every inflate routes
through ``InstanceManager.ensure_awake``, so N concurrent requests to
one hibernating tenant share a single batched (vectored) inflate.

The policy is intentionally simple (LRU deflate / TTL), matching the
paper's platform assumptions; FaasCache-style smarter keep-alive is noted
as related work, not reproduced.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Set, Tuple

from repro.core.forecast import ForecastDaemon
from repro.core.state import RUNG_OF, ContainerState, Rung
from repro.serving.engine import (SLO_BATCH, Request, Response,
                                  ServingEngine, TenantMigrated)

S = ContainerState


class AdmissionError(RuntimeError):
    """A tenant's queue is full: the request was rejected at admission.

    ``retry_after_s`` is the platform's backoff hint — predicted wake
    cost of the tenant's current rung plus the queued work ahead of the
    rejected request (what a gateway surfaces as ``Retry-After``)."""

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


@dataclass
class PlatformPolicy:
    keep_warm_s: float = 5.0            # idle time before deflation (④)
    memory_target_bytes: Optional[int] = None
    deflate_instead_of_evict: bool = True   # the paper's knob: off = classic
    predictive_wake: bool = False           # ⑤ wake on queue arrival
    #: anticipatory wake (⑤, "platform predicts a request"): wake a
    #: hibernated tenant when the EWMA of its inter-arrival gap says the
    #: next request is due within this margin (seconds); None disables
    anticipate_margin_s: Optional[float] = None
    ewma_alpha: float = 0.3
    #: admission control: max queued requests per tenant before rejection
    max_queue_depth: int = 64
    #: admission for batch-SLO requests; None inherits max_queue_depth.
    #: Under pressure the gateway sheds batch first, so a lower batch
    #: depth keeps background work from starving interactive admission
    max_queue_depth_batch: Optional[int] = None
    #: cadence of the background policy daemon (AsyncPlatform only)
    tick_interval_s: float = 0.05


class AsyncPlatform:
    """Event-driven single-node serverless platform over a
    :class:`ServingEngine`.

    ``arch_of``: instance id -> arch key for the engine factory (requests
    are keyed by instance id; cold starts look the arch up here).

    Use as a context manager (or call ``start()``/``stop()``)::

        with AsyncPlatform(engine, policy, arch_of, workers=4) as plat:
            futs = [plat.submit(req) for req in reqs]
            resps = [f.result() for f in futs]
    """

    def __init__(self, engine: ServingEngine, policy: PlatformPolicy,
                 arch_of: Dict[str, str], workers: int = 4):
        self.engine = engine
        self.policy = policy
        self.arch_of = arch_of
        self.workers = workers
        #: per-tenant FIFO of (request, future); insertion-ordered dict
        self.queues: Dict[str, Deque[Tuple[Request, Future]]] = {}
        self._cv = threading.Condition()
        self._busy: Set[str] = set()          # tenants claimed by a worker
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.log: List[tuple] = []
        # ONE arrival model for the whole node: the governor owns the
        # per-tenant EWMA; anticipatory wakes and victim selection read
        # the same prediction.  The platform policy's alpha applies only
        # when the user did not configure the governor explicitly — an
        # explicit GovernorConfig wins.
        if engine.manager.cfg.governor_policy is None:
            engine.manager.governor.cfg.ewma_alpha = policy.ewma_alpha
        # every eviction (keep-alive OR governor TERMINATED) must drop
        # this platform's per-tenant queue entry and serve lock
        engine.manager.on_evict = self._forget_tenant
        self.rejected = 0
        #: EWMA of per-request service seconds (feeds retry-after hints)
        self._service_ewma = 0.05
        #: cluster hook: ``reroute(iid, reqs, futs) -> bool`` takes over a
        #: batch whose tenant migrated off this node (the router resolves
        #: the futures against the target node).  Without it, stragglers
        #: fail with :class:`TenantMigrated` on their futures.
        self.reroute = None
        #: forecast control plane: created lazily on the first policy
        #: pass that sees the governor running a TrafficForecaster
        #: (``GovernorConfig.forecast``); None in the reactive world
        self._forecast_daemon: Optional[ForecastDaemon] = None

    @property
    def arrivals(self) -> Dict[str, tuple]:
        """Per-tenant arrival model (last_arrival_ts, ewma_gap_s) —
        owned by the manager's MemoryGovernor."""
        return self.engine.manager.governor.arrivals

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "AsyncPlatform":
        if self._threads:
            return self
        self._stop.clear()
        for i in range(self.workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"platform-worker-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._daemon_loop,
                             name="platform-daemon", daemon=True)
        t.start()
        self._threads.append(t)
        return self

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        if drain:
            self.drain(timeout)
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout)
        self._threads = []

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until every queued request has been served (or timeout).
        Returns True if fully drained."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while any(self.queues.values()) or self._busy:
                if not self._cv.wait(min(0.1, max(0.0, deadline -
                                                  time.monotonic()))):
                    if time.monotonic() >= deadline:
                        return False
        return True

    def __enter__(self) -> "AsyncPlatform":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- requests
    def submit(self, req: Request, now: Optional[float] = None) -> Future:
        """Enqueue a request; returns a future resolving to its
        :class:`Response` (or raising :class:`AdmissionError` if the
        tenant's queue is full)."""
        fut: Future = Future()
        now = now if now is not None else time.monotonic()
        depth = self.policy.max_queue_depth
        if req.slo == SLO_BATCH and \
                self.policy.max_queue_depth_batch is not None:
            depth = self.policy.max_queue_depth_batch
        with self._cv:
            q = self.queues.setdefault(req.instance_id, deque())
            if len(q) >= depth:
                self.rejected += 1
                self.log.append((now, "rejected", req.instance_id))
                fut.set_exception(AdmissionError(
                    f"tenant {req.instance_id}: {req.slo} queue depth "
                    f">= {depth}",
                    retry_after_s=self.retry_after_s(req.instance_id)))
                return fut
            q.append((req, fut))
            self._note_arrival(req.instance_id, now)
            self._cv.notify()
        if self.policy.predictive_wake:
            # ⑤ request arrival wakes a hibernated tenant off the serve
            # path — the streamed pipeline at low priority; the request
            # that triggered it is absorbed mid-stream via demand-pull
            if self.engine.manager.ensure_awake(
                    req.instance_id, trigger="sigcont",
                    priority="low") is not None:
                self.log.append((now, "predictive_wake", req.instance_id))
        return fut

    def fail_pending(self, exc: BaseException) -> int:
        """Crash path (``Node.kill``): resolve every queued future with
        ``exc`` and empty the queues.  Requests already claimed by a
        worker fail on their own when the engine call errors; the point
        here is that nothing stays parked waiting for a node that will
        never serve again.  Returns the number of requests failed."""
        failed = 0
        with self._cv:
            for q in self.queues.values():
                while q:
                    _, fut = q.popleft()
                    if not fut.done():
                        fut.set_exception(exc)
                    failed += 1
            self._cv.notify_all()
        return failed

    def _forget_tenant(self, iid: str) -> None:
        """Drop an evicted tenant's empty queue and serve lock; both are
        recreated on the next submit/cold-start."""
        with self._cv:
            q = self.queues.get(iid)
            if q is not None and not q:
                del self.queues[iid]
        self.engine.drop_instance_lock(iid)

    def _note_arrival(self, iid: str, now: float) -> None:
        self.engine.manager.governor.observe_arrival(iid, now)

    def retry_after_s(self, iid: str) -> float:
        """Backoff hint for a rejected request: the tenant's predicted
        wake cost at its current rung (per-rung EWMA the governor
        learned) plus the queue ahead at the measured per-request
        service rate.  This is what makes a gateway 429 honest — the
        client comes back when the node can plausibly serve it."""
        mgr = self.engine.manager
        wake = 0.0
        inst = mgr.instances.get(iid)
        if inst is not None:
            rung = RUNG_OF.get(inst.state, Rung.WARM)
            if rung != Rung.WARM:
                wake = mgr.governor.wake_cost(rung)
        with self._cv:
            depth = len(self.queues.get(iid, ()))
        return max(0.05, wake + depth * self._service_ewma)

    # ------------------------------------------------------------- serving
    def _claim(self):
        """With ``_cv`` held: pop the whole queue of the first unclaimed
        tenant with work (one claim = one continuous batch).  Tenants
        whose queue head is interactive-SLO are claimed before tenants
        with only batch work — the gateway's SLO classes reach the
        worker pool here."""
        batch_pick = None
        for iid, q in self.queues.items():
            if not q or iid in self._busy:
                continue
            if q[0][0].slo == SLO_BATCH:
                if batch_pick is None:
                    batch_pick = iid
                continue
            return self._claim_tenant(iid)
        if batch_pick is not None:
            return self._claim_tenant(batch_pick)
        return None

    def _claim_tenant(self, iid: str):
        q = self.queues[iid]
        reqs, futs = [], []
        while q:
            r, f = q.popleft()
            reqs.append(r)
            futs.append(f)
        self._busy.add(iid)
        return iid, reqs, futs

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                claim = self._claim()
                while claim is None:
                    if self._stop.is_set():
                        return
                    self._cv.wait(0.1)
                    claim = self._claim()
            iid, reqs, futs = claim
            try:
                self._serve(iid, reqs, futs)
            finally:
                with self._cv:
                    self._busy.discard(iid)
                    self._cv.notify_all()

    def _serve(self, iid: str, reqs: List[Request],
               futs: List[Future]) -> None:
        try:
            mgr = self.engine.manager
            if iid not in mgr.instances and iid not in mgr.migrated:
                # first request of an unknown tenant: specialize a zygote
                # (warm fork) when the pool holds one for this family;
                # fall back to the classic cold init otherwise
                arch = self.arch_of[iid]
                if self.engine.fork_instance(iid, arch) is not None:
                    self.log.append((time.monotonic(), "fork_start", iid))
                else:
                    self.engine.start_instance(iid, arch)
                    self.log.append((time.monotonic(), "cold_start", iid))
            t0 = time.monotonic()
            resps = self.engine.serve_batch(iid, reqs)
            per_req = (time.monotonic() - t0) / max(len(reqs), 1)
            self._service_ewma += 0.3 * (per_req - self._service_ewma)
            for f, r in zip(futs, resps):
                f.set_result(r)
        except TenantMigrated as e:
            # the tenant lives on another node now: hand the batch to the
            # cluster router (it resolves the futures against the target)
            if self.reroute is not None and self.reroute(iid, reqs, futs):
                self.log.append((time.monotonic(), "rerouted", iid))
                return
            for f in futs:
                if not f.done():
                    f.set_exception(e)
        except BaseException as e:
            for f in futs:
                if not f.done():
                    f.set_exception(e)

    # ------------------------------------------------------------- policy
    def _daemon_loop(self) -> None:
        while not self._stop.wait(self.policy.tick_interval_s):
            try:
                self.policy_pass()
            except Exception as e:       # policy must never kill the daemon
                self.log.append((time.monotonic(), "policy_error", repr(e)))

    def policy_pass(self, now: Optional[float] = None) -> List[str]:
        """One policy sweep: keep-alive deflation (or eviction), memory
        pressure, anticipatory wakes.  Instances currently serving are
        skipped via non-blocking per-instance locks."""
        now = now if now is not None else time.monotonic()
        mgr = self.engine.manager
        acted = []
        # every rung above HIBERNATE ages out: a tenant the governor
        # parked at MMAP_CLEAN/PARTIAL during a transient breach must not
        # pin its resident prefix forever once pressure clears
        idle_states = (S.WARM, S.WOKEN, S.MMAP_CLEAN, S.PARTIAL)
        for iid, inst in list(mgr.instances.items()):
            idle = now - inst.last_used
            if inst.state not in idle_states or \
                    idle <= self.policy.keep_warm_s:
                continue
            lock = self.engine.instance_lock(iid)
            if not lock.acquire(blocking=False):
                continue                       # in-flight request: not idle
            try:
                if inst.state not in idle_states:
                    continue
                if self.policy.deflate_instead_of_evict:
                    mgr.descend(iid, Rung.HIBERNATED)
                    self.log.append((now, "deflate", iid))
                else:
                    mgr.evict(iid)         # on_evict hook forgets the tenant
                    self.log.append((now, "evict", iid))
                acted.append(iid)
            finally:
                lock.release()
        # memory pressure: the governor walks victims down the deflation
        # ladder (cost/benefit, proportional reclaim).  The platform-level
        # target (if set) overrides the manager's configured node budget.
        if self.policy.memory_target_bytes is not None or \
                mgr.cfg.memory_budget_bytes is not None:
            acted += mgr.handle_memory_pressure(
                self.policy.memory_target_bytes,
                try_lock=self.engine.instance_lock, now=now)
        # ⑤ anticipatory SIGCONT: wake tenants whose EWMA inter-arrival
        # model predicts a request within the margin.  These run the SAME
        # streamed wake pipeline as request-driven wakes, at low priority
        # (no read double-buffering, yields between chunks) — a request
        # landing mid-stream is absorbed by demand-pulling its chunks
        if self.policy.anticipate_margin_s is not None:
            for iid, inst in list(mgr.instances.items()):
                if inst.state not in (S.HIBERNATE, S.PARTIAL, S.MMAP_CLEAN):
                    continue
                last, gap = self.arrivals.get(iid, (None, None))
                if last is None or gap is None:
                    continue
                due_in = (last + gap) - now
                if due_in <= self.policy.anticipate_margin_s:
                    if mgr.ensure_awake(iid, trigger="sigcont",
                                        priority="low") is not None:
                        self.log.append((now, "anticipated_wake", iid))
                        acted.append(iid)
        # forecast-driven pre-inflate: with a TrafficForecaster on the
        # governor, seasonal/flash-crowd predictions wake tenants (and
        # revive their spilled prefixes) *ahead* of the memoryless EWMA
        # above — the daemon rides the same policy cadence and the same
        # low-priority streamed wake pipeline
        if mgr.governor.forecaster is not None:
            if self._forecast_daemon is None:
                self._forecast_daemon = ForecastDaemon(mgr, self.arch_of)
            for iid in self._forecast_daemon.step(now):
                self.log.append((now, "forecast_wake", iid))
                acted.append(iid)
        # zygote TTL: retire donors idle past retire_idle_s even without
        # memory pressure (the governor handles the pressure-driven case)
        if mgr.zygotes is not None:
            for zid in mgr.zygotes.reap_idle(now):
                self.log.append((now, "zygote_retire", zid))
                acted.append(zid)
        return acted


class Platform(AsyncPlatform):
    """Synchronous compatibility shim over :class:`AsyncPlatform`.

    No threads: ``step()`` drains the per-tenant queues inline (grouped
    per instance for batching, as before) and ``tick()`` runs one policy
    pass.  ``submit`` still returns a future, already resolved by the
    time ``step()`` returns.
    """

    def __init__(self, engine: ServingEngine, policy: PlatformPolicy,
                 arch_of: Dict[str, str]):
        super().__init__(engine, policy, arch_of, workers=0)

    def submit(self, req: Request, now: Optional[float] = None) -> Future:
        """Like the async submit, but admission rejection raises
        immediately: legacy callers ignore the returned future, and a
        rejection parked on it would silently drop the request."""
        fut = super().submit(req, now)
        if fut.done() and fut.exception() is not None:
            raise fut.exception()
        return fut

    def step(self) -> List[Response]:
        """Drain the queues once (grouped per instance for batching)."""
        out: List[Response] = []
        while True:
            with self._cv:
                claim = self._claim()
            if claim is None:
                return out
            iid, reqs, futs = claim
            try:
                self._serve(iid, reqs, futs)
            finally:
                with self._cv:
                    self._busy.discard(iid)
            out.extend(f.result() for f in futs)

    def tick(self, now: Optional[float] = None) -> List[str]:
        """Apply keep-alive/pressure/anticipation policy once."""
        return self.policy_pass(now)

"""Serverless platform scheduler: routing, keep-alive and deflation policy.

This is the control plane of Fig. 3: it decides when a Warm Container is
deflated (④ SIGSTOP under memory pressure or keep-alive expiry), when a
Hibernate Container is predictively woken (⑤ SIGCONT), and routes incoming
requests to instances (cold-starting when none exists).

The policy is intentionally simple (LRU deflate / TTL), matching the
paper's platform assumptions; FaasCache-style smarter keep-alive is noted
as related work, not reproduced.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core.state import ContainerState
from repro.serving.engine import Request, Response, ServingEngine

S = ContainerState


@dataclass
class PlatformPolicy:
    keep_warm_s: float = 5.0            # idle time before deflation (④)
    memory_target_bytes: Optional[int] = None
    deflate_instead_of_evict: bool = True   # the paper's knob: off = classic
    predictive_wake: bool = False           # ⑤ wake on queue arrival
    #: anticipatory wake (⑤, "platform predicts a request"): wake a
    #: hibernated tenant when the EWMA of its inter-arrival gap says the
    #: next request is due within this margin (seconds); None disables
    anticipate_margin_s: Optional[float] = None
    ewma_alpha: float = 0.3


class Platform:
    """Single-node serverless platform over a :class:`ServingEngine`."""

    def __init__(self, engine: ServingEngine, policy: PlatformPolicy,
                 arch_of: Dict[str, str]):
        """``arch_of``: function name -> arch key for the engine factory."""
        self.engine = engine
        self.policy = policy
        self.arch_of = arch_of
        self.queue: Deque[Request] = deque()
        self._ids = 0
        self.log: List[tuple] = []
        #: per-tenant arrival model: (last_arrival_ts, ewma_gap_s)
        self.arrivals: Dict[str, tuple] = {}

    # ------------------------------------------------------------- requests
    def submit(self, req: Request, now: Optional[float] = None) -> None:
        self.queue.append(req)
        now = now if now is not None else time.monotonic()
        last, gap = self.arrivals.get(req.instance_id, (None, None))
        if last is not None:
            a = self.policy.ewma_alpha
            gap = (now - last) if gap is None else \
                a * (now - last) + (1 - a) * gap
        self.arrivals[req.instance_id] = (now, gap)
        if self.policy.predictive_wake:
            inst = self.engine.manager.instances.get(req.instance_id)
            if inst is not None and inst.state == S.HIBERNATE:
                self.engine.manager.predictive_wake(req.instance_id)
                self.log.append((now, "predictive_wake", req.instance_id))

    def step(self) -> List[Response]:
        """Drain the queue once (grouped per instance for batching)."""
        by_inst: Dict[str, List[Request]] = {}
        while self.queue:
            r = self.queue.popleft()
            by_inst.setdefault(r.instance_id, []).append(r)
        out: List[Response] = []
        for iid, reqs in by_inst.items():
            if iid not in self.engine.manager.instances:
                self.engine.start_instance(iid, self.arch_of[iid])
                self.log.append((time.monotonic(), "cold_start", iid))
            out.extend(self.engine.serve_batch(iid, reqs))
        return out

    # ------------------------------------------------------------- policy
    def tick(self, now: Optional[float] = None) -> List[str]:
        """Apply keep-alive policy: deflate (or evict) idle instances."""
        now = now if now is not None else time.monotonic()
        mgr = self.engine.manager
        acted = []
        for iid, inst in list(mgr.instances.items()):
            idle = now - inst.last_used
            if inst.state in (S.WARM, S.WOKEN) and \
                    idle > self.policy.keep_warm_s:
                if self.policy.deflate_instead_of_evict:
                    mgr.deflate(iid)
                    self.log.append((now, "deflate", iid))
                else:
                    mgr.evict(iid)
                    self.log.append((now, "evict", iid))
                acted.append(iid)
        if self.policy.memory_target_bytes is not None:
            acted += mgr.handle_memory_pressure(
                self.policy.memory_target_bytes)
        # ⑤ anticipatory SIGCONT: wake tenants whose EWMA inter-arrival
        # model predicts a request within the margin
        if self.policy.anticipate_margin_s is not None:
            for iid, inst in mgr.instances.items():
                if inst.state != S.HIBERNATE:
                    continue
                last, gap = self.arrivals.get(iid, (None, None))
                if last is None or gap is None:
                    continue
                due_in = (last + gap) - now
                if due_in <= self.policy.anticipate_margin_s:
                    mgr.predictive_wake(iid)
                    self.log.append((now, "anticipated_wake", iid))
                    acted.append(iid)
        return acted

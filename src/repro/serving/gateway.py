"""Async network gateway: HTTP/1.1 streaming + WebSocket over asyncio.

The protocol half of the front door (:mod:`repro.serving.frontdoor`
owns admission and SLO policy).  Hand-rolled on ``asyncio`` streams —
no external HTTP dependency — because the serving surface is small and
the latency path matters:

* ``POST /v1/generate`` — body ``{"tenant", "session", "prompt": [ids],
  "max_new_tokens", "slo", "arch"?, "close"?, "idempotency_key"?}``
  (the key makes retries safe across node crashes — the front door
  re-dispatches and dedups re-played tokens); the response is
  ``Transfer-Encoding: chunked`` NDJSON, one ``{"token": t}`` line per
  generated token (flushed immediately — the client's TTFT is the
  engine's first-token time, which on a woken tenant tracks the wake
  pipeline's critical prefix) and a final ``{"done": true, ...}`` line.
* ``GET /v1/ws`` — RFC 6455 WebSocket: each text frame is one request
  (same JSON), answered by per-token text frames and a ``done`` frame;
  multiple requests may flow over one socket sequentially.
* ``GET /healthz``, ``GET /v1/stats`` — liveness and counters.

Overload is an HTTP status, not a queue: :class:`Backpressure` from the
front door (session caps, per-tenant queue depth, pressure shedding)
becomes ``429 Too Many Requests`` with a ``Retry-After`` header derived
from learned wake costs — the client backs off instead of parking work
on the node that is busiest deflating.

The event loop runs on a dedicated thread; engine workers push tokens
via ``TokenStream.push`` and the loop is woken per token with
``call_soon_threadsafe`` — tokens cross threads, never block the loop.
"""
from __future__ import annotations

import asyncio
import base64
import hashlib
import json
import math
import struct
import threading
from typing import Optional, Tuple

from repro.serving.frontdoor import Backpressure, FrontDoor, TokenStream

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
_MAX_BODY = 8 << 20
_MAX_HEADER = 64 << 10


class Gateway:
    """Serve a :class:`FrontDoor` over a loopback (or LAN) socket."""

    def __init__(self, door: FrontDoor, host: str = "127.0.0.1",
                 port: int = 0):
        self.door = door
        self.host = host
        self.port = port
        self.address: Optional[Tuple[str, int]] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> Tuple[str, int]:
        if self._thread is not None:
            return self.address
        started = threading.Event()
        boot_err: list = []

        def run() -> None:
            loop = asyncio.new_event_loop()
            self._loop = loop
            asyncio.set_event_loop(loop)
            try:
                self._server = loop.run_until_complete(
                    asyncio.start_server(self._handle_conn, self.host,
                                         self.port))
                self.address = self._server.sockets[0].getsockname()[:2]
            except BaseException as e:      # port in use, bad host, ...
                boot_err.append(e)
                loop.close()
                return
            finally:
                started.set()
            try:
                loop.run_forever()
            finally:
                self._server.close()
                loop.run_until_complete(self._server.wait_closed())
                tasks = asyncio.all_tasks(loop)
                for t in tasks:
                    t.cancel()
                if tasks:
                    loop.run_until_complete(
                        asyncio.gather(*tasks, return_exceptions=True))
                loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="gateway-loop")
        self._thread.start()
        started.wait()
        if boot_err:
            self._thread.join()
            self._thread = None
            raise boot_err[0]
        return self.address

    def close(self) -> None:
        if self._loop is None:
            return
        try:
            self._loop.call_soon_threadsafe(self._loop.stop)
        except RuntimeError:
            pass                            # loop already closed
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._thread = None
        self._loop = None

    def __enter__(self) -> "Gateway":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ http core
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
            if len(head) > _MAX_HEADER:
                raise ValueError("oversized request head")
            request_line, headers = self._parse_head(head)
            method, path, _version = request_line
            if headers.get("upgrade", "").lower() == "websocket":
                await self._serve_ws(reader, writer, headers)
                return
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n > _MAX_BODY:
                await self._respond(writer, 413, {"error": "body too "
                                                  "large"})
                return
            if n:
                body = await reader.readexactly(n)
            await self._route(writer, method, path, body)
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError,
                ConnectionError, asyncio.CancelledError):
            pass
        except Exception as e:
            try:
                await self._respond(writer, 400,
                                    {"error": f"{type(e).__name__}: {e}"})
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    @staticmethod
    def _parse_head(head: bytes):
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3:
            raise ValueError(f"bad request line: {lines[0]!r}")
        headers = {}
        for ln in lines[1:]:
            if not ln:
                continue
            k, _, v = ln.partition(":")
            headers[k.strip().lower()] = v.strip()
        return (parts[0], parts[1], parts[2]), headers

    async def _respond(self, writer, status: int, obj,
                       extra_headers: str = "") -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  413: "Payload Too Large", 429: "Too Many Requests",
                  500: "Internal Server Error"}.get(status, "")
        body = (json.dumps(obj) + "\n").encode()
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n{extra_headers}"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()

    async def _route(self, writer, method: str, path: str,
                     body: bytes) -> None:
        if method == "GET" and path == "/healthz":
            await self._respond(writer, 200, {"ok": True})
        elif method == "GET" and path == "/v1/stats":
            await self._respond(writer, 200, self.door.stats())
        elif method == "POST" and path == "/v1/generate":
            await self._generate(writer, body)
        else:
            await self._respond(writer, 404, {"error": f"no route "
                                              f"{method} {path}"})

    # ------------------------------------------------------------ generate
    def _submit(self, spec: dict) -> TokenStream:
        return self.door.submit(
            spec["tenant"], spec.get("prompt", [1, 2, 3]),
            session_id=spec.get("session", "s0"),
            max_new_tokens=int(spec.get("max_new_tokens", 8)),
            slo=spec.get("slo", "interactive"),
            arch_key=spec.get("arch"),
            close_session=bool(spec.get("close", False)),
            idempotency_key=spec.get("idempotency_key"))

    async def _generate(self, writer, body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            stream = self._submit(spec)
        except Backpressure as e:
            await self._respond(
                writer, 429,
                {"error": str(e), "retry_after_s": e.retry_after_s},
                extra_headers=(f"Retry-After: "
                               f"{math.ceil(e.retry_after_s)}\r\n"))
            return
        except (KeyError, ValueError, TypeError) as e:
            await self._respond(writer, 400,
                                {"error": f"{type(e).__name__}: {e}"})
            return

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()

        async def send_line(obj) -> None:
            data = (json.dumps(obj) + "\n").encode()
            writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
            await writer.drain()

        try:
            async for tok in self._tokens(stream):
                await send_line({"token": tok})
            err = stream.error
            if err is not None:
                await send_line({"done": True, "error": str(err)})
            else:
                resp = stream.response
                ttft = stream.ttft_s()
                await send_line({
                    "done": True,
                    "tokens": len(resp.tokens) if resp else 0,
                    "state_before": resp.state_before if resp else "",
                    "ttft_ms": None if ttft is None else ttft * 1e3,
                })
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass                        # client went away mid-stream

    async def _tokens(self, stream: TokenStream):
        """Async token iterator over a worker-thread-fed stream."""
        loop = asyncio.get_running_loop()
        event = asyncio.Event()
        stream.waker = lambda: loop.call_soon_threadsafe(event.set)
        try:
            while True:
                for tok in stream.drain_nowait():
                    yield tok
                if stream.done:
                    for tok in stream.drain_nowait():
                        yield tok
                    return
                await asyncio.wait_for(event.wait(), timeout=300.0)
                event.clear()
        finally:
            stream.waker = None

    # ------------------------------------------------------------ websocket
    async def _serve_ws(self, reader, writer, headers) -> None:
        key = headers.get("sec-websocket-key", "")
        if not key:
            await self._respond(writer, 400, {"error": "missing "
                                              "Sec-WebSocket-Key"})
            return
        accept = base64.b64encode(hashlib.sha1(
            (key + _WS_GUID).encode()).digest()).decode()
        writer.write(
            "HTTP/1.1 101 Switching Protocols\r\n"
            "Upgrade: websocket\r\nConnection: Upgrade\r\n"
            f"Sec-WebSocket-Accept: {accept}\r\n\r\n".encode())
        await writer.drain()
        while True:
            msg = await self._ws_recv(reader, writer)
            if msg is None:
                return
            try:
                spec = json.loads(msg)
                stream = self._submit(spec)
            except Backpressure as e:
                await self._ws_send(writer, json.dumps(
                    {"error": str(e),
                     "retry_after_s": e.retry_after_s}))
                continue
            except (KeyError, ValueError, TypeError) as e:
                await self._ws_send(writer, json.dumps(
                    {"error": f"{type(e).__name__}: {e}"}))
                continue
            async for tok in self._tokens(stream):
                await self._ws_send(writer, json.dumps({"token": tok}))
            if stream.error is not None:
                await self._ws_send(writer, json.dumps(
                    {"done": True, "error": str(stream.error)}))
            else:
                ttft = stream.ttft_s()
                await self._ws_send(writer, json.dumps(
                    {"done": True,
                     "ttft_ms": None if ttft is None else ttft * 1e3}))

    async def _ws_recv(self, reader, writer) -> Optional[str]:
        """One text message (no fragmentation support); answers pings;
        ``None`` on close."""
        while True:
            hdr = await reader.readexactly(2)
            fin, opcode = hdr[0] & 0x80, hdr[0] & 0x0F
            masked, ln = hdr[1] & 0x80, hdr[1] & 0x7F
            if ln == 126:
                ln = struct.unpack(">H", await reader.readexactly(2))[0]
            elif ln == 127:
                ln = struct.unpack(">Q", await reader.readexactly(8))[0]
            if ln > _MAX_BODY:
                raise ValueError("oversized websocket frame")
            mask = await reader.readexactly(4) if masked else b""
            data = await reader.readexactly(ln)
            if mask:
                data = bytes(b ^ mask[i % 4] for i, b in enumerate(data))
            if opcode == 0x8:                       # close
                await self._ws_send_raw(writer, 0x8, data[:2])
                return None
            if opcode == 0x9:                       # ping -> pong
                await self._ws_send_raw(writer, 0xA, data)
                continue
            if opcode == 0xA:                       # pong
                continue
            if opcode != 0x1 or not fin:
                raise ValueError("only unfragmented text frames are "
                                 "supported")
            return data.decode("utf-8")

    async def _ws_send(self, writer, text: str) -> None:
        await self._ws_send_raw(writer, 0x1, text.encode("utf-8"))

    @staticmethod
    async def _ws_send_raw(writer, opcode: int, data: bytes) -> None:
        n = len(data)
        if n < 126:
            head = bytes([0x80 | opcode, n])
        elif n < (1 << 16):
            head = bytes([0x80 | opcode, 126]) + struct.pack(">H", n)
        else:
            head = bytes([0x80 | opcode, 127]) + struct.pack(">Q", n)
        writer.write(head + data)
        await writer.drain()


def ws_client_handshake(sock, host: str, path: str = "/v1/ws") -> None:
    """Minimal client-side WebSocket handshake over a connected socket
    (tests and benchmarks; real clients bring their own stack)."""
    key = base64.b64encode(hashlib.sha1(str(id(sock)).encode())
                           .digest()[:16]).decode()
    sock.sendall(
        f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
        f"Upgrade: websocket\r\nConnection: Upgrade\r\n"
        f"Sec-WebSocket-Key: {key}\r\n"
        f"Sec-WebSocket-Version: 13\r\n\r\n".encode())
    buf = b""
    while b"\r\n\r\n" not in buf:
        chunk = sock.recv(4096)
        if not chunk:
            raise ConnectionError("handshake failed")
        buf += chunk
    status = buf.split(b"\r\n", 1)[0]
    if b"101" not in status:
        raise ConnectionError(f"upgrade refused: {status!r}")
    want = base64.b64encode(hashlib.sha1(
        (key + _WS_GUID).encode()).digest())
    if want not in buf:
        raise ConnectionError("bad Sec-WebSocket-Accept")


def ws_client_send(sock, text: str) -> None:
    """Send one masked client text frame (RFC 6455 requires masking)."""
    import os
    data = text.encode("utf-8")
    mask = os.urandom(4)
    masked = bytes(b ^ mask[i % 4] for i, b in enumerate(data))
    n = len(data)
    if n < 126:
        head = bytes([0x81, 0x80 | n])
    elif n < (1 << 16):
        head = bytes([0x81, 0x80 | 126]) + struct.pack(">H", n)
    else:
        head = bytes([0x81, 0x80 | 127]) + struct.pack(">Q", n)
    sock.sendall(head + mask + masked)


def ws_client_recv(sock) -> Optional[str]:
    """Receive one server text frame; ``None`` on close."""
    def rx(n):
        buf = b""
        while len(buf) < n:
            c = sock.recv(n - len(buf))
            if not c:
                raise ConnectionError("closed mid-frame")
            buf += c
        return buf
    while True:
        hdr = rx(2)
        opcode, ln = hdr[0] & 0x0F, hdr[1] & 0x7F
        if ln == 126:
            ln = struct.unpack(">H", rx(2))[0]
        elif ln == 127:
            ln = struct.unpack(">Q", rx(8))[0]
        data = rx(ln)
        if opcode == 0x8:
            return None
        if opcode in (0x9, 0xA):
            continue
        return data.decode("utf-8")

"""Serving engine: request execution over hibernatable model instances.

The engine is the "container runtime" side of the paper: it executes user
requests (prefill + decode) against :class:`ModelInstance`s, drives the
container state machine, performs *residency faulting* (the page-fault
swap-in analogue: before compute touches a weight unit or KV page, any
non-resident unit is loaded from the swap files), and feeds the REAP
recorder with the exact unit set a request touches.

Weight residency uses a fixpoint loop: units known statically (non-expert
leaves, embedding blocks of the request's tokens) are faulted up-front;
MoE expert units are faulted as the router reveals them (experts are only
knowable by running the model — the same reason the paper needs a *sample
request* to record the working set).

Compiled functions are cached per ``(kind, batch, seq-bucket)`` in
``inst.compiled`` — they survive hibernation (the paper's kept-alive
"blocked runtime threads"), which is exactly why a woken container skips
the cold-start cost.

Concurrency: each instance has a re-entrant serve lock
(:meth:`ServingEngine.instance_lock`); ``serve_batch`` holds it for the
whole request, so the AsyncPlatform's worker pool can serve *different*
instances in parallel while each instance's state machine stays
race-free.  Wakes route through ``InstanceManager.ensure_awake`` so a
wake storm on one hibernating tenant performs exactly one inflate.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instance import ModelInstance
from repro.core.manager import InstanceManager
from repro.core.metrics import LatencyTrace
from repro.core.state import ContainerState, Event
from repro.models import model
from repro.serving.paged_kv import PagedKVCache

S = ContainerState


# ---------------------------------------------------------------------------
# requests / responses
# ---------------------------------------------------------------------------

class TenantMigrated(RuntimeError):
    """The tenant no longer lives on this node: its snapshot migrated to
    ``target`` (a peer node id, or ``None`` if unknown).  The cluster
    router catches this and re-dispatches the request there."""

    def __init__(self, instance_id: str, target: Optional[str] = None):
        super().__init__(
            f"tenant {instance_id} migrated away"
            + (f" to node {target}" if target else ""))
        self.instance_id = instance_id
        self.target = target


class NodeDownError(RuntimeError):
    """The node serving (or queued to serve) this request crashed.  The
    request itself may be retried elsewhere — the front door re-submits
    under the same idempotency key once the router re-homes the tenant,
    and the token stream deduplicates any tokens the first attempt
    already emitted."""


#: SLO classes the front door stamps on requests: interactive work
#: drives high-priority wakes and is claimed first by the worker pool;
#: batch work rides low-priority (yielding) wakes and is shed first
#: under admission pressure.
SLO_INTERACTIVE = "interactive"
SLO_BATCH = "batch"


@dataclass
class Request:
    instance_id: str
    session_id: str
    prompt: np.ndarray                       # (S,) int32 token ids
    max_new_tokens: int = 8
    embeds: Optional[np.ndarray] = None      # VLM stub patch embeddings
    frames: Optional[np.ndarray] = None      # audio stub encoder frames
    close_session: bool = False
    #: SLO class (``SLO_INTERACTIVE`` / ``SLO_BATCH``)
    slo: str = SLO_INTERACTIVE
    #: token-level streaming sink: called with each generated token id as
    #: it is produced — the first call fires right after prefill, i.e. as
    #: soon as the wake pipeline's critical prefix is resident, so a
    #: streaming client's TTFT tracks the wake path, not full inflate.
    #: Must be cheap and must not raise (failures are swallowed).
    on_token: Optional[Callable[[int], None]] = field(
        default=None, repr=False, compare=False)

    def emit(self, token: int) -> None:
        if self.on_token is not None:
            try:
                self.on_token(token)
            except Exception:
                pass        # a broken stream sink must not kill the batch


@dataclass
class Response:
    request: Request
    tokens: List[int] = field(default_factory=list)
    state_before: str = ""
    state_after: str = ""
    spans: Dict[str, float] = field(default_factory=dict)
    faulted_bytes: int = 0
    faults: int = 0
    prefetched_bytes: int = 0
    #: True when prefill was skipped entirely: the prompt's KV pages were
    #: COW-adopted from the deployment prefix registry
    adopted_prefix: bool = False


# ---------------------------------------------------------------------------
# jitted compute (cached per instance)
# ---------------------------------------------------------------------------

def _make_prefill(cfg, window):
    def f(params, tokens, embeds, frames):
        x, caches, aux = model.forward_hidden(
            params, cfg, tokens, embeds=embeds, enc_frames=frames,
            window=window, collect_cache=True)
        logits = model.unembed(params, cfg, x[:, -1])
        return logits, caches, aux
    return jax.jit(f)


def _make_decode(cfg, window):
    def f(params, tokens, cache):
        return model.decode_step(params, cfg, tokens, cache,
                                 window=window, with_aux=True)
    return jax.jit(f)


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, manager: InstanceManager, *, max_new_default: int = 8,
                 window: Optional[int] = None):
        self.manager = manager
        self.window = window
        self.max_new_default = max_new_default
        self.trace = LatencyTrace()
        self._locks: Dict[str, threading.RLock] = {}
        self._locks_guard = threading.Lock()
        # the zygote pool compiles through the engine: spawned donors get
        # their prefill executables pre-built so a fork inherits them
        zp = manager.zygotes
        if zp is not None and zp.precompile is None:
            zp.precompile = self.precompile_prefill

    def instance_lock(self, instance_id: str) -> threading.RLock:
        """Per-instance serve lock: held for the whole of ``serve_batch``;
        the platform's policy daemon try-acquires it before deflating so
        SIGSTOP never races an in-flight request."""
        with self._locks_guard:
            lock = self._locks.get(instance_id)
            if lock is None:
                lock = self._locks[instance_id] = threading.RLock()
            return lock

    def drop_instance_lock(self, instance_id: str) -> None:
        """Forget an evicted instance's lock (tenant churn must not grow
        the lock table unboundedly)."""
        with self._locks_guard:
            self._locks.pop(instance_id, None)

    # ------------------------------------------------------------ lifecycle
    def start_instance(self, instance_id: str, arch_key: str,
                       shared_paths=None) -> ModelInstance:
        """Cold start (①): init/load + attach the paged cache."""
        with self.trace.span("cold_start"):
            inst = self.manager.cold_start(instance_id, arch_key,
                                           shared_paths=shared_paths)
            inst.kv = PagedKVCache(instance_id, inst.cfg, self.manager.pool,
                                   registry=self.manager.prefix_registry)
        return inst

    def fork_instance(self, instance_id: str, arch_key: str,
                      shared_paths=None) -> Optional[ModelInstance]:
        """Fork admission: specialize a live zygote of ``arch_key`` into
        a new tenant (warm weights memcpy, inherited compiled prefill,
        shared base by refcount) and attach a fresh paged cache.  Returns
        None when no zygote is available — callers fall back to
        ``start_instance``.  A concurrent fork of the same tenant dedups
        below (the returned instance may already carry a cache)."""
        with self.trace.span("fork_start"):
            inst = self.manager.fork_start(instance_id, arch_key,
                                           shared_paths=shared_paths)
            if inst is not None and inst.kv is None:
                inst.kv = PagedKVCache(instance_id, inst.cfg,
                                       self.manager.pool,
                                       registry=self.manager.prefix_registry)
        return inst

    def precompile_prefill(self, inst: ModelInstance) -> None:
        """Pre-build the prefill executables for a zygote — the cold-start
        cost a fork skips.  Each configured prompt length is compiled by
        an actual dummy dispatch (jit tracing alone would defer the XLA
        compile to the first real request); lengths that cannot run on
        dummy inputs (frontend archs wanting embeds/frames) are skipped —
        the fork still wins on init, just not on compile."""
        zp = self.manager.zygotes
        lens = zp.cfg.precompile_prompt_lens if zp is not None else (8,)
        params = inst.params_pytree()
        for L in lens:
            try:
                fn = self._compiled(inst, "prefill", 1, int(L),
                                    False, False)
                logits, _, _ = fn(params,
                                  jnp.zeros((1, int(L)), jnp.int32),
                                  None, None)
                jax.block_until_ready(logits)
            except Exception:
                continue

    def _compiled(self, inst: ModelInstance, kind: str, B: int, Sb: int,
                  has_embeds: bool, has_frames: bool):
        key = (kind, B, Sb, has_embeds, has_frames)
        fn = inst.compiled.get(key)
        if fn is None:
            maker = _make_prefill if kind == "prefill" else _make_decode
            fn = maker(inst.cfg, self.window)
            inst.compiled[key] = fn
        return fn

    # ------------------------------------------------------------ weights
    def _static_weight_keys(self, inst: ModelInstance,
                            tokens: np.ndarray) -> List[Tuple]:
        """Units knowable before execution: non-expert leaves + embedding
        blocks of the tokens in this request."""
        keys = []
        eb = inst.embed_block
        blocks = {int(t) // eb for t in np.asarray(tokens).ravel()}
        # tied embeddings: the LM head reads the WHOLE table every step,
        # so all embed blocks belong to the static access set
        all_embed = inst.cfg.tie_embeddings
        for u in inst.units.values():
            if u.path in inst.shared_paths:
                continue
            if u.path == "embed" and u.sub >= 0:
                if all_embed or u.sub in blocks:
                    keys.append(u.key)
            elif u.sub < 0 or "/moe/" not in u.path:
                keys.append(u.key)
        return keys

    def _embed_keys(self, inst: ModelInstance, tokens) -> List[Tuple]:
        """Embedding blocks for a set of token ids (decode feeds generated
        tokens whose rows may still be swapped out)."""
        eb = inst.embed_block
        blocks = {int(t) // eb for t in np.asarray(tokens).ravel()}
        return [u.key for u in inst.units.values()
                if u.path == "embed" and u.sub in blocks
                and u.path not in inst.shared_paths]

    def _expert_keys(self, inst: ModelInstance,
                     counts: np.ndarray) -> List[Tuple]:
        """Expert units fired by the router.  counts: (..., E) summed."""
        if counts is None:
            return []
        used = np.asarray(counts).reshape(-1, counts.shape[-1]).sum(0)
        keys = []
        for u in inst.units.values():
            if u.sub >= 0 and "/moe/" in u.path and used[u.sub] > 0:
                keys.append(u.key)
        return keys

    def _fault(self, inst: ModelInstance, keys: Sequence[Tuple],
               resp: Response) -> None:
        missing = [k for k in keys
                   if (k[0] == "w" and k not in inst.resident)]
        kv_missing = (inst.kv.nonresident_keys(
            [k for k in keys if k[0] in ("kv", "kvh")])
            if inst.kv is not None else [])
        if not missing and not kv_missing:
            return
        st = self.manager.hib.fault(inst, missing + kv_missing)
        resp.faulted_bytes += st.faulted_bytes
        resp.faults += st.faults
        inst.recorder.record_many(missing + kv_missing)
        # serviced faults become lookahead: asynchronously pull the next
        # layer's KV pages / adjacent embed blocks so the following step
        # hits residency instead of faulting
        if self.manager.cfg.lookahead:
            la = self._lookahead_keys(inst, missing + kv_missing)
            if la:
                self.manager.hib.prefetch_async(inst, la)

    def _lookahead_keys(self, inst: ModelInstance,
                        faulted: Sequence[Tuple]) -> List[Tuple]:
        """Predict the fault set's successors: when layer *k*'s KV page
        faults, layer *k+1*'s page (and the session's next page in the
        same layer) is about to be touched; when an embedding block
        faults mid-decode, its neighbour is the next most likely row
        block.  Weight leaves are layer-stacked, so weight-side lookahead
        only applies to embed blocks."""
        out: List[Tuple] = []
        kv = inst.kv
        for k in faulted:
            if k[0] == "kv" and kv is not None:
                _, sid, layer, pidx = k
                sess = kv.sessions.get(sid)
                if sess is None:
                    continue
                succ = [(layer + 1, pidx), (layer, pidx + 1)]
                for lyr, p in succ:
                    if lyr < len(sess.pages) and p < len(sess.pages[lyr]) \
                            and sess.pages[lyr][p] is None:
                        out.append(("kv", sid, lyr, p))
            elif k[0] == "w" and k[1] == "embed" and k[2] >= 0:
                nk = ("w", "embed", k[2] + 1)
                if nk in inst.units and nk not in inst.resident:
                    out.append(nk)
        return [k for k in dict.fromkeys(out)]

    # ------------------------------------------------------------ cache io
    def _dense_cache(self, inst: ModelInstance, sids: List[str],
                     max_len: int):
        """Gather sessions' pages into a dense decode cache pytree."""
        cfg, kv = inst.cfg, inst.kv
        L, B = cfg.num_layers, len(sids)
        layers: Dict[str, np.ndarray] = {}
        lengths = np.zeros((B,), np.int32)
        kv_positions = np.full((B, max_len), -1, np.int32)
        te = kv.token_elems
        if cfg.attention == "mla":
            r, rd = cfg.mla.kv_lora_rank, cfg.mla.qk_rope_head_dim
            layers["ckv"] = np.zeros((L, B, max_len, r), np.float32)
            layers["krope"] = np.zeros((L, B, max_len, rd), np.float32)
        elif cfg.attention == "gqa":
            Hkv, D = cfg.num_kv_heads, cfg.head_dim
            layers["k"] = np.zeros((L, B, max_len, Hkv, D), np.float32)
            layers["v"] = np.zeros((L, B, max_len, Hkv, D), np.float32)
        host: Dict[str, List[np.ndarray]] = {}
        for b, sid in enumerate(sids):
            sess = kv.sessions[sid]
            n = sess.num_tokens
            lengths[b] = n
            kv_positions[b, :n] = np.arange(n)
            if te:
                for l in range(L):
                    data = kv.read_tokens(sid, l, n)       # (n, te)
                    if cfg.attention == "mla":
                        layers["ckv"][l, b, :n] = data[:, :r]
                        layers["krope"][l, b, :n] = data[:, r:]
                    else:
                        Hkv, D = cfg.num_kv_heads, cfg.head_dim
                        kd = data.reshape(n, 2, Hkv, D)
                        layers["k"][l, b, :n] = kd[:, 0]
                        layers["v"][l, b, :n] = kd[:, 1]
            for key, arr in sess.host_units.items():
                kind = key[3]
                if arr is None:
                    raise KeyError(key)
                host.setdefault(kind, [None] * B)[b] = arr
        for kind, rows in host.items():
            layers[kind] = np.stack(rows, axis=1)          # (L, B, ...)
        dtype = jnp.dtype(cfg.dtype)
        jl = {k: jnp.asarray(v, jnp.float32 if k == "state" else dtype)
              for k, v in layers.items()}
        return {"layers": jl,
                "lengths": jnp.asarray(lengths),
                "kv_positions": jnp.asarray(kv_positions)}

    def _writeback(self, inst: ModelInstance, sids: List[str], cache,
                   start_lens: np.ndarray, resp: Optional[Response]) -> None:
        """Write new tokens' KV + final host units back into pages."""
        cfg, kv = inst.cfg, inst.kv
        L = cfg.num_layers
        layers = {k: np.asarray(v) for k, v in cache["layers"].items()}
        lengths = np.asarray(cache["lengths"])
        touched: List[Tuple] = []
        for b, sid in enumerate(sids):
            sess = kv.sessions[sid]
            n0, n1 = int(start_lens[b]), int(lengths[b])
            sess.num_tokens = n1
            if kv.token_elems and n1 > n0:
                for l in range(L):
                    if cfg.attention == "mla":
                        new = np.concatenate(
                            [layers["ckv"][l, b, n0:n1],
                             layers["krope"][l, b, n0:n1]], -1)
                    else:
                        new = np.stack([layers["k"][l, b, n0:n1],
                                        layers["v"][l, b, n0:n1]], 1)
                    touched += kv.write_tokens(
                        sid, l, new.reshape(n1 - n0, kv.token_elems), n0)
            for kind in ("state", "conv", "cross_k", "cross_v"):
                if kind in layers:
                    touched.append(kv.set_host_unit(
                        sid, "all", kind, layers[kind][:, b]))
        inst.recorder.record_many(touched)

    # ------------------------------------------------------------ serving
    def handle(self, req: Request) -> Response:
        """End-to-end single request (the Fig. 6 measurement path)."""
        return self.serve_batch(req.instance_id, [req])[0]

    def serve_batch(self, instance_id: str,
                    reqs: List[Request]) -> List[Response]:
        """Continuous-batched execution of requests on one instance:
        per-request prefill, then a joint decode loop that sessions leave
        as they finish."""
        with self.instance_lock(instance_id):
            return self._serve_batch_locked(instance_id, reqs)

    def _serve_batch_locked(self, instance_id: str,
                            reqs: List[Request]) -> List[Response]:
        inst = self.manager.instances.get(instance_id)
        # in-flight-request handoff: a request landing on a MIGRATING
        # tenant blocks on the transfer handle (exactly like late wake
        # arrivals block on the shared wake pipeline), then either serves
        # locally (transfer aborted -> HIBERNATE) or reroutes (committed:
        # the tenant now lives on the target node)
        while inst is not None and inst.state == S.MIGRATING:
            self.manager.ensure_awake(instance_id, trigger="request")
            inst = self.manager.instances.get(instance_id)
        if inst is not None and inst.state == S.DEAD \
                and inst.migration is not None:
            # commit window: MIGRATE_DONE has fired but the source has
            # not detached yet — wait for the commit to finish (placement
            # and the forwarding address are recorded before the handle
            # resolves) rather than serving a weight-dropped husk
            inst.migration.wait()
            inst = self.manager.instances.get(instance_id)
            if inst is None or inst.state == S.DEAD:
                raise TenantMigrated(instance_id,
                                     self.manager.migrated.get(instance_id))
        if inst is None:
            if instance_id in self.manager.migrated:
                raise TenantMigrated(instance_id,
                                     self.manager.migrated[instance_id])
            raise KeyError(f"instance {instance_id} not started")
        resps = [Response(r, state_before=inst.state.value) for r in reqs]
        t0 = time.monotonic()

        # SLO feeds the wake pipeline's priority: an all-batch claim
        # wakes low-priority (yielding, no double-buffer) so it never
        # contends with an interactive tenant's wake on the same store
        wake_priority = ("high" if any(r.slo != SLO_BATCH for r in reqs)
                         else "low")

        # ---- state machine: the request trigger (②⑥⑦ + ladder rungs)
        wake_stats = None
        if inst.state in (S.HIBERNATE, S.PARTIAL, S.WOKEN):
            if inst.state in (S.HIBERNATE, S.PARTIAL):
                # wake-storm guard: at most one batched inflate per cycle.
                # A PARTIAL wake is rung-aware: the critical prefix is
                # already resident, the cold tail restores behind us.
                wake_stats = self.manager.ensure_awake(
                    instance_id, trigger="request",
                    priority=wake_priority)
            inst.sm.fire(Event.REQUEST)       # -> HIBERNATE_RUNNING
            finish_to = S.WOKEN
        elif inst.state in (S.WARM, S.MMAP_CLEAN):
            if inst.state == S.MMAP_CLEAN:
                # re-map the shared base weights before compute touches them
                wake_stats = self.manager.ensure_awake(
                    instance_id, trigger="request",
                    priority=wake_priority)
            inst.sm.fire(Event.REQUEST)       # -> RUNNING
            finish_to = S.WARM
        else:
            raise RuntimeError(f"instance busy/unservable: {inst.state}")
        if wake_stats is not None:
            for r in resps:
                r.prefetched_bytes = wake_stats.prefetched_bytes

        # backpressure the wake stream while this request computes: the
        # tail pauses (it resumes after FINISH) and anything this request
        # needs arrives via demand-pull on our own thread
        pipe = inst.wake_pipeline
        if pipe is not None and pipe.active:
            pipe.backpressure(+1)
        else:
            pipe = None
        try:
            # ---- per-request prefill
            cfg = inst.cfg
            sids = []
            for req, resp in zip(reqs, resps):
                with self.trace.span("prefill"):
                    self._prefill_one(inst, req, resp)
                sids.append(req.session_id)

            # ---- joint decode
            active = [i for i, r in enumerate(reqs) if r.max_new_tokens > 0]
            if active:
                with self.trace.span("decode"):
                    self._decode_joint(inst, reqs, resps, sids)
        finally:
            if pipe is not None:
                pipe.backpressure(-1)

        # ---- finish (③⑧)
        inst.sm.fire(Event.FINISH)
        assert inst.state == finish_to
        inst.last_used = time.monotonic()
        for req in reqs:
            if req.close_session:
                inst.kv.close_session(req.session_id)
        for r in resps:
            r.state_after = inst.state.value
            r.spans["e2e"] = time.monotonic() - t0
        return resps

    # ------------------------------------------------------------ internals
    def _prefill_one(self, inst: ModelInstance, req: Request,
                     resp: Response) -> None:
        cfg = inst.cfg
        kv = inst.kv
        if req.session_id not in kv.sessions:
            if self._try_adopt_prefix(inst, req, resp):
                return
            kv.new_session(req.session_id)
        sess = kv.sessions[req.session_id]

        # fault statically-known weights + this session's existing cache
        static_keys = self._static_weight_keys(inst, req.prompt)
        self._fault(inst, static_keys, resp)
        inst.recorder.record_many(
            k for k in static_keys if k[0] == "w")
        if sess.num_tokens:
            prior = kv.keys_for(req.session_id, window_tokens=None)
            self._fault(inst, prior, resp)
            inst.recorder.record_many(prior)

        tokens = np.asarray(req.prompt, np.int32)[None]    # (1, S)
        Sb = tokens.shape[1]
        fn = self._compiled(inst, "prefill", 1, Sb,
                            req.embeds is not None, req.frames is not None)
        embeds = None if req.embeds is None else jnp.asarray(req.embeds)[None]
        frames = None if req.frames is None else jnp.asarray(req.frames)[None]

        # fixpoint on MoE expert residency.  The snapshot is taken BEFORE
        # dispatch: a concurrently streaming wake may install an expert
        # mid-run, and a post-run residency check would then accept logits
        # computed with zeroed (or torn) weights.  A key missing from the
        # pre-dispatch snapshot always forces one more run.
        for _ in range(8):
            snapshot = inst.resident.copy()
            params = inst.params_pytree()
            logits, caches, aux = fn(params, jnp.asarray(tokens),
                                     embeds, frames)
            ek = self._expert_keys(inst, aux.get("expert_counts"))
            missing = [k for k in ek if k not in snapshot]
            inst.recorder.record_many(ek)
            if not missing:
                break
            self._fault(inst, missing, resp)
        resp.tokens.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
        # first streamed token: fires as soon as prefill completes, which
        # on a woken tenant is right after the critical prefix landed
        req.emit(resp.tokens[-1])

        # write prefill KV into pages
        n0 = sess.num_tokens
        S_tot = Sb + (0 if req.embeds is None or cfg.is_encoder_decoder
                      else req.embeds.shape[0])
        layers = {} if caches is None else \
            {k: np.asarray(v) for k, v in caches.items()}
        touched: List[Tuple] = []
        if kv.token_elems:
            for l in range(cfg.num_layers):
                if cfg.attention == "mla":
                    new = np.concatenate([layers["ckv"][l, 0],
                                          layers["krope"][l, 0]], -1)
                else:
                    new = np.stack([layers["k"][l, 0], layers["v"][l, 0]], 1)
                touched += kv.write_tokens(
                    req.session_id, l,
                    new.reshape(S_tot, kv.token_elems), n0)
        for kind in ("state", "conv", "cross_k", "cross_v"):
            if kind in layers:
                touched.append(kv.set_host_unit(
                    req.session_id, "all", kind, layers[kind][:, 0]))
        sess.num_tokens = n0 + S_tot
        sess.token_ids += [int(t) for t in req.prompt]
        inst.recorder.record_many(touched)

        # a fresh prompt that just paid full prefill becomes a shareable
        # prefix: later sessions (any tenant of this arch, any node after
        # migration) COW-adopt these pages instead of recomputing
        registry = kv.registry
        if registry is not None and n0 == 0 and inst.arch_key \
                and req.embeds is None and req.frames is None:
            registry.register(inst.arch_key, kv, req.session_id,
                              resp.tokens[-1])

    def _try_adopt_prefix(self, inst: ModelInstance, req: Request,
                          resp: Response) -> bool:
        """Cross-tenant prefix adoption: if the prompt's salted token-hash
        is registered, map the existing KV pages by COW refcount and emit
        the recorded first token — no prefill forward pass at all.  Static
        weights still fault in (decode needs them); the prompt must be
        pure tokens (embeds/frames make KV depend on more than token ids).
        """
        kv = inst.kv
        registry = kv.registry
        if registry is None or not inst.arch_key or \
                req.embeds is not None or req.frames is not None or \
                len(req.prompt) < registry.min_tokens:
            return False
        entry = registry.lookup(inst.arch_key,
                                [int(t) for t in req.prompt])
        if entry is None:
            return False
        static_keys = self._static_weight_keys(inst, req.prompt)
        self._fault(inst, static_keys, resp)
        inst.recorder.record_many(k for k in static_keys if k[0] == "w")
        registry.adopt(entry.digest, kv, req.session_id)
        resp.adopted_prefix = True
        resp.tokens.append(entry.first_token)
        req.emit(resp.tokens[-1])
        inst.recorder.record_many(kv.keys_for(req.session_id))
        return True

    def _decode_joint(self, inst: ModelInstance, reqs: List[Request],
                      resps: List[Response], sids: List[str]) -> None:
        cfg = inst.cfg
        kv = inst.kv
        max_new = max(r.max_new_tokens for r in reqs)
        max_len = _bucket(max(kv.sessions[s].num_tokens for s in sids)
                          + max_new)
        # fault every page the decode window will read
        for sid in sids:
            self._fault(inst, kv.keys_for(sid), resps[0])
            inst.recorder.record_many(kv.keys_for(sid))
        cache = self._dense_cache(inst, sids, max_len)
        start_lens = np.asarray(cache["lengths"]).copy()
        B = len(sids)
        fn = self._compiled(inst, "decode", B, max_len, False, False)
        cur = jnp.asarray([r.tokens[-1] if r.tokens else 0 for r in resps],
                          jnp.int32)
        done = np.zeros((B,), bool)
        for _step in range(max_new - 1 + 1):
            # the fed-back tokens' embedding rows page-fault on access
            ek = self._embed_keys(inst, np.asarray(cur))
            inst.recorder.record_many(ek)
            self._fault(inst, ek, resps[0])
            # page-fault-and-retry on expert residency: re-run the SAME
            # step from the pre-step cache until every routed expert was
            # resident in the PRE-dispatch snapshot (see _prefill_one for
            # why the snapshot must precede the run)
            for _ in range(4):
                snapshot = inst.resident.copy()
                params = inst.params_pytree()
                logits, new_cache, aux = fn(params, cur, cache)
                counts = aux.get("expert_counts")
                if counts is None:
                    break
                ek = self._expert_keys(inst, np.asarray(counts))
                inst.recorder.record_many(ek)
                missing = [k for k in ek if k not in snapshot]
                if not missing:
                    break
                self._fault(inst, missing, resps[0])
            cache = new_cache
            nxt = np.asarray(jnp.argmax(
                logits[:, :cfg.vocab_size], axis=-1), np.int32)
            for b, r in enumerate(resps):
                want = r.request.max_new_tokens
                if not done[b] and len(r.tokens) < want:
                    r.tokens.append(int(nxt[b]))
                    r.request.emit(r.tokens[-1])
                    if len(r.tokens) >= want:
                        done[b] = True
                else:
                    done[b] = True
            cur = jnp.asarray(nxt)
            if done.all():
                break
        self._writeback(inst, sids, cache, start_lens, resps[0])

    # ------------------------------------------------------------ REAP ops
    def record_sample(self, instance_id: str, req: Request) -> frozenset:
        """§3.4.2 Record process: run a sample request with the recorder on;
        the union of touched units becomes the REAP working set."""
        inst = self.manager.instances[instance_id]
        inst.recorder.start()
        self.handle(req)
        return inst.recorder.stop()

from repro.serving.engine import Request, Response, ServingEngine
from repro.serving.paged_kv import KVSession, PagedKVCache
from repro.serving.scheduler import (AdmissionError, AsyncPlatform,
                                     Platform, PlatformPolicy)

__all__ = ["Request", "Response", "ServingEngine", "KVSession",
           "PagedKVCache", "AdmissionError", "AsyncPlatform",
           "Platform", "PlatformPolicy"]
# repro.serving.paged_backend bridges the cache to the Pallas kernel
# (imported lazily: it pulls in the kernels package)

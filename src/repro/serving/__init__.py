from repro.serving.engine import (SLO_BATCH, SLO_INTERACTIVE, Request,
                                  Response, ServingEngine)
from repro.serving.frontdoor import (Backpressure, FrontDoor,
                                     FrontDoorPolicy, TokenStream)
from repro.serving.gateway import Gateway
from repro.serving.paged_kv import KVSession, PagedKVCache
from repro.serving.scheduler import (AdmissionError, AsyncPlatform,
                                     Platform, PlatformPolicy)

__all__ = ["Request", "Response", "ServingEngine", "KVSession",
           "PagedKVCache", "AdmissionError", "AsyncPlatform",
           "Platform", "PlatformPolicy", "SLO_INTERACTIVE", "SLO_BATCH",
           "FrontDoor", "FrontDoorPolicy", "TokenStream", "Backpressure",
           "Gateway"]
# repro.serving.paged_backend bridges the cache to the Pallas kernel
# (imported lazily: it pulls in the kernels package)
